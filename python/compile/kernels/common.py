"""Shared helpers for the Pallas kernels.

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness (and AOT) path;
TPU performance is *estimated* from BlockSpec geometry (DESIGN.md #Perf).

Tile sizes default to MXU-friendly shapes (multiples of 8x128 lanes would
be the TPU layout; we use 32..128 squares which keep the VMEM footprint of
a (bm x bk) + (bk x bn) + (bm x bn) int32 working set under 4 MiB).
"""

from __future__ import annotations

import jax.numpy as jnp

INT = jnp.int32
WIDE = jnp.int64

# Flag threaded into every pallas_call; kept in one place so a TPU build
# only has to flip it here.
INTERPRET = True


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: jnp.ndarray, axis: int, multiple: int, value: int = 0) -> jnp.ndarray:
    """Zero-pad `axis` of x up to the next multiple of `multiple`."""
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, multiple - rem)
    return jnp.pad(x, pads, constant_values=value)
