//! v3 binary container (`model.nemob`): bit-identity across every load
//! path (mmap, aligned read, v2 JSON, in-memory deploy) at Q in
//! {1, 2, 4, 8}, the zero-copy borrowed-storage accounting, the
//! on-disk size contract, and loud typed rejection of corrupted
//! containers — truncation mid-section, flipped weight bytes,
//! misaligned offsets, header/section-table mismatches
//! (DESIGN.md §Artifact-format).

use std::time::Duration;

use nemo::coordinator::{Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::engine::IntegerEngine;
use nemo::exec::{ExecInput, Executor, NativeIntExecutor};
use nemo::io::artifact::{
    binary_info, ArtifactError, DeployedArtifact, BIN_ALIGN, BIN_MAGIC, BIN_VERSION,
};
use nemo::io::BinLoadMode;
use nemo::model::mlp;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::quantize_input;
use nemo::tensor::TensorF;
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn tmp_path(tag: &str, ext: &str) -> std::path::PathBuf {
    // pid-unique: concurrent test runs on one host must not share files.
    std::env::temp_dir().join(format!("nemo_nemob_{tag}_{}.{ext}", std::process::id()))
}

/// An MLP deployed on a Q-bit activation grid (4-bit weights below Q=8
/// so the sections land on sub-byte dtypes, 8-bit at Q=8) — the same
/// proven few-bit pipeline tests/subbyte.rs exercises.
fn deployed_mlp(q: u32, seed: u64) -> (Network<IntegerDeployable>, TensorF) {
    let wbits = if q < 8 { 4 } else { 8 };
    let mut rng = Rng::new(seed);
    let g = mlp(&mut rng, 12, 10, 4, 1.0 / 255.0);
    let x = TensorF::from_vec(
        &[3, 12],
        (0..36).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );
    let fp = Network::from_graph(g).unwrap();
    let betas = fp.calibrate(&[x.clone()]);
    let nid = fp
        .quantize_pact(wbits, q, &betas)
        .unwrap()
        .deploy(DeployOptions { wbits, abits: q, ..DeployOptions::default() })
        .unwrap()
        .integerize();
    (nid, x)
}

fn deployed_synthnet(seed: u64) -> Network<IntegerDeployable> {
    let mut rng = Rng::new(seed);
    SynthNet::init(&mut rng)
        .to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize()
}

/// Rebuild a container around an edited header: preamble + new header
/// length + the original payload region re-based onto the new 64-byte
/// payload base. Section offsets are payload-relative, so untouched
/// entries stay valid across the edit.
fn rewrite_header(file: &[u8], edit: impl Fn(&str) -> String) -> Vec<u8> {
    let header_len = u32::from_le_bytes(file[12..16].try_into().unwrap()) as usize;
    let old_base = (16 + header_len).div_ceil(BIN_ALIGN) * BIN_ALIGN;
    let htext = std::str::from_utf8(&file[16..16 + header_len]).unwrap();
    let edited = edit(htext);
    let new_base = (16 + edited.len()).div_ceil(BIN_ALIGN) * BIN_ALIGN;
    let mut out = vec![0u8; new_base + (file.len() - old_base)];
    out[..8].copy_from_slice(&BIN_MAGIC);
    out[8..12].copy_from_slice(&BIN_VERSION.to_le_bytes());
    out[12..16].copy_from_slice(&(edited.len() as u32).to_le_bytes());
    out[16..16 + edited.len()].copy_from_slice(edited.as_bytes());
    out[new_base..].copy_from_slice(&file[old_base..]);
    out
}

#[test]
fn bit_identity_across_all_load_paths_at_every_q() {
    for q in [1u32, 2, 4, 8] {
        let (nid, x) = deployed_mlp(q, 60 + q as u64);
        let qx = quantize_input(&x, 1.0 / 255.0);
        let jpath = tmp_path(&format!("q{q}"), "nemo.json");
        let bpath = tmp_path(&format!("q{q}"), "nemob");
        nid.save_deployed(&jpath).unwrap();
        nid.save_deployed_bin(&bpath).unwrap();

        // The reference: the in-memory deployment, interpreter semantics.
        let want = nid.run(&qx);

        let jart = DeployedArtifact::load(&jpath).unwrap();
        assert_eq!(
            IntegerEngine::new().run(&jart.graph, &qx),
            want,
            "JSON load diverged at Q={q}"
        );

        for mode in [BinLoadMode::Read, BinLoadMode::Mmap, BinLoadMode::Auto] {
            let (bart, prov, stats) = match DeployedArtifact::load_binary(&bpath, mode) {
                Ok(t) => t,
                // mmap may legitimately be unavailable off-unix; the
                // other modes must always work.
                Err(_) if mode == BinLoadMode::Mmap && cfg!(not(unix)) => continue,
                Err(e) => panic!("load_binary({mode:?}) failed at Q={q}: {e}"),
            };
            assert_eq!(prov.format_version, BIN_VERSION as i64);
            assert_eq!(
                bart.graph.precisions(),
                nid.int_graph().precisions(),
                "precision stamps changed at Q={q}"
            );
            assert_eq!(
                IntegerEngine::new().run(&bart.graph, &qx),
                want,
                "binary {mode:?} load diverged at Q={q}"
            );
            if cfg!(target_endian = "little") {
                assert_eq!(stats.copied_bytes, 0, "copy on {mode:?} at Q={q}");
                assert!(stats.borrowed_bytes > 0);
            }
            // Executor path: the plan compiled from the binary artifact
            // matches the in-memory network bit for bit.
            let e0 = nid.to_executor(3).unwrap();
            let e1 = NativeIntExecutor::new(bart.graph.clone(), 3).unwrap();
            assert_eq!(e0.packed(), e1.packed(), "plan choice changed at Q={q}");
            let o0 = e0.run_batch(&ExecInput::i32(qx.clone())).unwrap();
            let o1 = e1.run_batch(&ExecInput::i32(qx.clone())).unwrap();
            assert_eq!(
                o0.int_logits().unwrap(),
                o1.int_logits().unwrap(),
                "executor logits diverged ({mode:?}, Q={q})"
            );
        }
        let _ = std::fs::remove_file(&jpath);
        let _ = std::fs::remove_file(&bpath);
    }
}

#[test]
fn zero_copy_accounting_and_disk_size_contract() {
    let nid = deployed_synthnet(7);
    let path = tmp_path("stats", "nemob");
    nid.save_deployed_bin(&path).unwrap();
    let info = binary_info(&path).unwrap();
    assert_eq!(info.container_version, BIN_VERSION);
    assert!(info.sections.len() >= 2, "synthnet must ship several sections");
    let section_bytes: usize = info.sections.iter().map(|s| s.bytes).sum();
    assert_eq!(info.weight_bytes, section_bytes);

    // On-disk weight region (including alignment padding) stays within
    // 1.1x of the raw packed weight bytes.
    assert!(
        (info.aligned_weight_bytes as f64) <= 1.1 * info.weight_bytes as f64,
        "alignment padding blew the size contract: {} aligned vs {} raw",
        info.aligned_weight_bytes,
        info.weight_bytes
    );

    let (_, _, stats) = DeployedArtifact::load_binary(&path, BinLoadMode::Read).unwrap();
    assert_eq!(stats.sections, info.sections.len());
    assert!(!stats.mmap);
    if cfg!(target_endian = "little") {
        assert_eq!(
            stats.borrowed_bytes, info.weight_bytes,
            "every weight byte must be served as a borrowed view"
        );
        assert_eq!(stats.copied_bytes, 0);
    }
    match DeployedArtifact::load_binary(&path, BinLoadMode::Mmap) {
        Ok((_, _, stats)) => {
            assert!(stats.mmap);
            if cfg!(target_endian = "little") {
                assert_eq!(stats.borrowed_bytes, info.weight_bytes);
                assert_eq!(stats.copied_bytes, 0, "mmap path must not copy weight bytes");
            }
        }
        Err(e) => assert!(cfg!(not(unix)), "mmap load must succeed on unix: {e}"),
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupted_containers_are_rejected_loudly() {
    let nid = deployed_synthnet(11);
    let path = tmp_path("corrupt", "nemob");
    nid.save_deployed_bin(&path).unwrap();
    let file = std::fs::read(&path).unwrap();
    let info = binary_info(&path).unwrap();
    assert!(DeployedArtifact::load_binary(&path, BinLoadMode::Read).is_ok());

    // 1. Truncation mid-section: cut the file inside the last section.
    let last = info.sections.last().unwrap().clone();
    let cut = info.payload_base + last.off + last.bytes / 2;
    std::fs::write(&path, &file[..cut]).unwrap();
    match DeployedArtifact::load_binary(&path, BinLoadMode::Read) {
        Err(ArtifactError::Binary(msg)) => assert!(msg.contains("truncated"), "{msg}"),
        other => panic!("expected Binary(truncated), got {:?}", other.err()),
    }

    // 2. Flipped byte inside a weight section: the per-section checksum
    //    names the section, and the model never reaches the engines.
    let mut flipped = file.clone();
    flipped[info.payload_base + info.sections[0].off] ^= 0xff;
    std::fs::write(&path, &flipped).unwrap();
    match DeployedArtifact::load_binary(&path, BinLoadMode::Read) {
        Err(ArtifactError::Checksum { stored, .. }) => {
            assert!(stored.contains("section 0"), "{stored}");
        }
        other => panic!("expected per-section Checksum, got {:?}", other.err()),
    }

    // 3. Misaligned section offset. The model checksum does not cover
    //    the section table, so the alignment gate is the one that fires.
    let off_field = format!("\"off\":{}", info.sections[1].off);
    let misaligned = rewrite_header(&file, |h| {
        assert!(h.contains(&off_field), "section 1 off not found in header");
        h.replacen(&off_field, &format!("\"off\":{}", info.sections[1].off + 1), 1)
    });
    std::fs::write(&path, &misaligned).unwrap();
    match DeployedArtifact::load_binary(&path, BinLoadMode::Read) {
        Err(ArtifactError::Binary(msg)) => assert!(msg.contains("aligned"), "{msg}"),
        other => panic!("expected Binary(misaligned), got {:?}", other.err()),
    }

    // 4. Header/section-table mismatch: a table entry no weight
    //    references violates exactly-once consumption. The ghost is
    //    zero-length with the empty-payload FNV-1a64 checksum (the
    //    offset basis), so only the consumption check can fire.
    let last_end = last.off + last.bytes;
    let extra_off = last_end.div_ceil(BIN_ALIGN) * BIN_ALIGN;
    let ghost = format!(
        "{{\"bytes\":0,\"checksum\":\"fnv1a64:cbf29ce484222325\",\
         \"dtype\":\"i8\",\"name\":\"ghost\",\"off\":{extra_off},\"shape\":[0]}}"
    );
    let mut mismatched = rewrite_header(&file, |h| {
        assert!(h.contains("}],\"version\""), "section table terminator not found");
        h.replacen("}],\"version\"", &format!("}},{ghost}],\"version\""), 1)
    });
    // Pad the payload region so the ghost's aligned offset is in bounds
    // and the structural check is the one that trips.
    mismatched.extend(std::iter::repeat(0u8).take(extra_off - last_end));
    std::fs::write(&path, &mismatched).unwrap();
    match DeployedArtifact::load_binary(&path, BinLoadMode::Read) {
        Err(ArtifactError::Binary(msg)) => assert!(msg.contains("not referenced"), "{msg}"),
        other => panic!("expected Binary(unreferenced section), got {:?}", other.err()),
    }

    // 5. Unsupported container version in the preamble.
    let mut vbump = file.clone();
    vbump[8..12].copy_from_slice(&(BIN_VERSION + 1).to_le_bytes());
    std::fs::write(&path, &vbump).unwrap();
    match DeployedArtifact::load_binary(&path, BinLoadMode::Read) {
        Err(ArtifactError::Version { found }) => assert_eq!(found, (BIN_VERSION + 1) as i64),
        other => panic!("expected Version error, got {:?}", other.err()),
    }

    // 6. A preamble shorter than 16 bytes.
    std::fs::write(&path, &file[..10]).unwrap();
    assert!(matches!(
        DeployedArtifact::load_binary(&path, BinLoadMode::Read),
        Err(ArtifactError::Binary(_))
    ));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn registry_serves_both_formats_and_hot_swaps_bit_identically() {
    // The CI round-trip in miniature: one model saved in both formats,
    // served from one registry, then the JSON-backed entry hot-swapped
    // onto the binary artifact — logits bit-identical throughout.
    let nid = deployed_synthnet(19);
    let jpath = tmp_path("serve", "nemo.json");
    let bpath = tmp_path("serve", "nemob");
    nid.save_deployed(&jpath).unwrap();
    nid.save_deployed_bin(&bpath).unwrap();

    let server = Server::builder()
        .default_config(ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_micros(300),
            n_workers: 2,
        })
        .model_from_artifact("json", &jpath)
        .model_from_artifact("bin", &bpath)
        .start()
        .unwrap();
    let h = server.handle();
    let mut data = SynthDigits::new(3);
    let (x, _) = data.batch(2);
    let qx = quantize_input(&x, EPS_IN);
    let want = nid.run(&qx);
    assert_eq!(h.infer("json", qx.clone()).unwrap(), want);
    assert_eq!(h.infer("bin", qx.clone()).unwrap(), want);

    // Hot-swap the JSON-backed entry onto the binary artifact.
    let v = h.swap_model_from_artifact("json", &bpath).unwrap();
    assert!(v >= 2, "swap must bump the model version, got v{v}");
    assert_eq!(
        h.infer("json", qx.clone()).unwrap(),
        want,
        "logits must be bit-identical after the JSON->binary hot swap"
    );
    let _ = server.stop();
    let _ = std::fs::remove_file(&jpath);
    let _ = std::fs::remove_file(&bpath);
}
