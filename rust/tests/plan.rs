//! Fused-vs-unfused bit-exactness: compiled execution plans must equal
//! the naive interpreters **node for node** (via `run_traced`) on
//! randomized graphs covering conv / linear / bn / thresh / requant /
//! add / pool combinations, at every representation (FP float graphs, QD
//! float twins, ID integer graphs) — plus handcrafted integer graphs
//! that defeat fusion (fanout on a conv output, standalone epilogue
//! ops). The precision-packed execution path (`packed_layout` /
//! `execute_packed`) is held to the same node-for-node standard on every
//! randomized graph — including sub-byte (Q in {1, 2, 4}) deployments
//! whose buffers are bit-packed and whose GEMMs may run bit-serial —
//! and its arena must never cost more bytes than the full-width one.

use nemo::engine::plan::{FloatArena, IntArena, PackedArena};
use nemo::engine::{FloatEngine, FloatPlan, IntPlan, IntegerEngine};
use nemo::graph::int::{IntGraph, IntOp};
use nemo::graph::{Graph, Op};
use nemo::network::Network;
use nemo::quant::bn::{BnParams, BnQuant, Thresholds};
use nemo::quant::requant::Requant;
use nemo::quant::{quantize_input, QuantSpec};
use nemo::tensor::{Tensor, TensorF, TensorI};
use nemo::transform::DeployOptions;
use nemo::util::prop::prop_check;
use nemo::util::rng::Rng;

fn rand_w(rng: &mut Rng, shape: &[usize], std: f64) -> TensorF {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal(0.0, std) as f32).collect())
}

fn rand_bn(rng: &mut Rng, c: usize) -> BnParams {
    BnParams {
        gamma: (0..c).map(|_| rng.uniform(0.3, 1.6)).collect(),
        sigma: (0..c).map(|_| rng.uniform(0.3, 1.6)).collect(),
        beta: (0..c).map(|_| rng.normal(0.0, 0.2)).collect(),
        mu: (0..c).map(|_| rng.normal(0.0, 0.2)).collect(),
    }
}

/// A random FullPrecision net: conv blocks with optional BN / residual
/// Add / pooling (max, avg and BN-or-act-after-pool variants), finished
/// by GlobalAvgPool-or-Flatten + Linear. Always validates.
fn random_net(rng: &mut Rng) -> (Graph, usize) {
    let mut g = Graph::new(1.0 / 255.0);
    let mut c = rng.int(1, 3) as usize;
    let mut h = 8usize;
    let mut prev = g.push("in", Op::Input { shape: vec![c, h, h] }, &[]);
    let blocks = rng.int(1, 3) as usize;
    for b in 0..blocks {
        let cout = rng.int(2, 6) as usize;
        let k = if rng.int(0, 2) == 0 { 1 } else { 3 };
        let pad = k / 2;
        let stride = if h % 2 == 0 && rng.int(0, 3) == 0 { 2 } else { 1 };
        let std = (0.8 / (c * k * k) as f64).sqrt();
        let bias = if rng.int(0, 2) == 0 {
            Some((0..cout).map(|_| rng.normal(0.0, 0.1)).collect())
        } else {
            None
        };
        let w = rand_w(rng, &[cout, c, k, k], std);
        prev = g.push(&format!("c{b}"), Op::Conv2d { w, bias, stride, pad }, &[prev]);
        h = (h + 2 * pad - k) / stride + 1;
        c = cout;
        if rng.int(0, 2) == 0 {
            prev = g.push(&format!("bn{b}"), Op::BatchNorm { bn: rand_bn(rng, c) }, &[prev]);
        }
        prev = g.push(&format!("a{b}"), Op::ReLU, &[prev]);
        // residual branch: conv-bn-act from the activation, then Add (+act)
        if rng.int(0, 3) == 0 {
            let std2 = (0.8 / (c * 9) as f64).sqrt();
            let w2 = rand_w(rng, &[c, c, 3, 3], std2);
            let cb = g.push(
                &format!("rc{b}"),
                Op::Conv2d { w: w2, bias: None, stride: 1, pad: 1 },
                &[prev],
            );
            let bb =
                g.push(&format!("rbn{b}"), Op::BatchNorm { bn: rand_bn(rng, c) }, &[cb]);
            let ab = g.push(&format!("ra{b}"), Op::ReLU, &[bb]);
            let add = g.push(&format!("radd{b}"), Op::Add, &[prev, ab]);
            prev = g.push(&format!("rpa{b}"), Op::ReLU, &[add]);
        }
        if h % 2 == 0 && h > 2 && rng.int(0, 2) == 0 {
            let pool = if rng.int(0, 2) == 0 {
                Op::MaxPool { k: 2 }
            } else {
                Op::AvgPool { k: 2 }
            };
            prev = g.push(&format!("p{b}"), pool, &[prev]);
            h /= 2;
            // BN or activation directly after a pool: exercises the
            // standalone (non-fused) epilogue steps of the plan.
            match rng.int(0, 3) {
                0 => {
                    prev = g.push(
                        &format!("pbn{b}"),
                        Op::BatchNorm { bn: rand_bn(rng, c) },
                        &[prev],
                    );
                    prev = g.push(&format!("pa{b}"), Op::ReLU, &[prev]);
                }
                1 => {
                    prev = g.push(&format!("pa{b}"), Op::ReLU, &[prev]);
                }
                _ => {}
            }
        }
    }
    let classes = rng.int(2, 6) as usize;
    let (head_in, head) = if rng.int(0, 2) == 0 {
        (c, g.push("gap", Op::GlobalAvgPool, &[prev]))
    } else {
        (c * h * h, g.push("fl", Op::Flatten, &[prev]))
    };
    let wf = rand_w(rng, &[head_in, classes], (1.0 / head_in as f64).sqrt());
    let fb = if rng.int(0, 2) == 0 {
        Some((0..classes).map(|_| rng.normal(0.0, 0.1)).collect())
    } else {
        None
    };
    g.push("fc", Op::Linear { w: wf, bias: fb }, &[head]);
    let in_c = match &g.nodes[0].op {
        Op::Input { shape } => shape[0],
        _ => unreachable!(),
    };
    (g, in_c)
}

fn rand_input(rng: &mut Rng, b: usize, c: usize) -> TensorF {
    Tensor::from_vec(
        &[b, c, 8, 8],
        (0..b * c * 64)
            .map(|_| rng.uniform(0.0, 1.0) as f32)
            .collect(),
    )
}

/// Plan trace must equal the interpreter trace at every fused anchor —
/// on the i32 path AND the precision-packed path, twice through each
/// arena (reuse must not leak state).
fn check_int_plan(g: &IntGraph, qx: &TensorI) {
    let interp = IntegerEngine::new().run_traced(g, qx);
    let plan = IntPlan::compile(g).expect("plan");
    let layout = plan.layout(qx.shape()[0]).expect("layout");
    let mut arena = IntArena::new();
    for round in 0..2 {
        let trace = plan.execute_traced(&layout, &mut arena, qx);
        for (node, t) in &trace {
            assert_eq!(
                t, &interp[*node],
                "round {round}: plan step for node {node} ({}) diverged",
                g.nodes[*node].name
            );
        }
        let out = plan.execute(&layout, &mut arena, qx);
        assert_eq!(out, interp[g.output], "round {round}: final output diverged");
    }

    // Packed path: bit-identical node for node, and never more arena
    // bytes than the i32 layout (sub-word slots shrink, wide slots tie;
    // the extra Input/Add slots are offset by byte sizing).
    let packed = plan.packed_layout(qx.shape()[0]).expect("packed layout");
    let mut parena = PackedArena::new();
    for round in 0..2 {
        let trace = plan.execute_packed_traced(&packed, &mut parena, qx);
        for (node, t) in &trace {
            assert_eq!(
                t, &interp[*node],
                "round {round}: packed step for node {node} ({}) diverged",
                g.nodes[*node].name
            );
        }
        let out = plan.execute_packed(&packed, &mut parena, qx);
        assert_eq!(out, interp[g.output], "round {round}: packed output diverged");
    }
    // Byte-sizing sanity: the packed layout's only structural additions
    // over the i32 one are the materialized input slot and full-width Add
    // accumulators (each bounded by one i32 arena); everything else can
    // only shrink. Strict savings on real deployed nets are asserted in
    // tests/precision.rs.
    assert!(
        packed.arena_bytes() <= 2 * layout.arena_bytes() + qx.len() * 4,
        "packed arena {} B wildly exceeds i32 arena {} B",
        packed.arena_bytes(),
        layout.arena_bytes()
    );
}

fn check_float_plan(g: &Graph, x: &TensorF) {
    let interp = FloatEngine::new().run_traced(g, x);
    let plan = FloatPlan::compile(g).expect("plan");
    let layout = plan.layout(x.shape()[0]).expect("layout");
    let mut arena = FloatArena::new();
    for (node, t) in plan.execute_traced(&layout, &mut arena, x) {
        assert_eq!(
            t.shape(),
            interp[node].shape(),
            "shape diverged at node {node}"
        );
        assert_eq!(
            t.data(),
            interp[node].data(),
            "plan step for node {node} ({}) diverged",
            g.nodes[node].name
        );
    }
}

#[test]
fn plans_match_interpreters_on_random_nets() {
    prop_check(20, |rng| {
        let (g, in_c) = random_net(rng);
        g.validate().map_err(|e| format!("generated invalid graph: {e}"))?;
        let b = rng.int(1, 4) as usize;
        let x = rand_input(rng, b, in_c);

        // FP float graph: fused float plan == float interpreter.
        check_float_plan(&g, &x);

        // Deploy (randomized options) and check the QD twin + ID graph.
        // Sub-byte activation grids (Q in {1, 2, 4}) route the packed
        // path through bit-packed buffers; 4-bit weights plus 1-/2-bit
        // activations additionally select the bit-serial GEMM.
        let fp = Network::from_graph(g).map_err(|e| e.to_string())?;
        let betas = fp.calibrate(&[x.clone()]);
        let abits = [1u32, 2, 4, 8][rng.int(0, 4) as usize];
        let wbits = [4u32, 8][rng.int(0, 2) as usize];
        let opts = DeployOptions {
            wbits,
            abits,
            use_thresholds: rng.int(0, 2) == 0,
            ..DeployOptions::default()
        };
        let dep = fp
            .quantize_pact(wbits, abits, &betas)
            .map_err(|e| e.to_string())?
            .deploy(opts)
            .map_err(|e| e.to_string())?
            .integerize()
            .into_deployed();

        let qx = quantize_input(&x, 1.0 / 255.0);
        let x_grid = qx.map(|q| q as f32 / 255.0);
        check_float_plan(&dep.qd, &x_grid);
        check_int_plan(&dep.id, &qx);
        Ok(())
    });
}

#[test]
fn fanout_defeats_fusion_but_not_correctness() {
    // conv output consumed by BOTH a bn-chain and a maxpool: the conv
    // must not absorb its epilogue, and every standalone op still
    // matches the interpreter.
    let mut rng = Rng::new(7);
    let mut g = IntGraph::default();
    let spec = QuantSpec { eps: 1.0 / 255.0, lo: 0, hi: 255 };
    let x = g.push("in", IntOp::Input { shape: vec![2, 4, 4], spec }, &[]);
    let wq = Tensor::from_vec(
        &[2, 3],
        (0..6).map(|_| rng.int(-4, 5) as i32).collect(),
    )
    .into();
    let conv = g.push(
        "conv",
        IntOp::ConvInt { wq, bias_q: Some(vec![7, -7, 0]), cin: 2, kh: 1, kw: 1, stride: 1, pad: 0 },
        &[x],
    );
    let bn = BnQuant {
        kappa_q: vec![2, -1, 3],
        lambda_q: vec![1, 2, -3],
        eps_kappa: 0.01,
        eps_phi_out: 0.001,
    };
    let bnn = g.push("bn", IntOp::IntBn { bn }, &[conv]);
    let rq = Requant { m: 5, d: 3, lo: 0, hi: 255 };
    let act = g.push("act", IntOp::RequantAct { rq }, &[bnn]);
    let pool = g.push("mp", IntOp::MaxPoolInt { k: 2 }, &[conv]); // 2nd consumer
    let pact = g.push(
        "pact",
        IntOp::RequantAct { rq: Requant { m: 3, d: 2, lo: 0, hi: 255 } },
        &[pool],
    );
    let f1 = g.push("f1", IntOp::Flatten, &[act]);
    let f2 = g.push("f2", IntOp::Flatten, &[pact]);
    let wl = Tensor::from_vec(&[48, 2], (0..96).map(|i| (i % 7) as i32 - 3).collect()).into();
    let l1 = g.push("fc1", IntOp::LinearInt { wq: wl, bias_q: None }, &[f1]);
    let wl2 = Tensor::from_vec(&[12, 2], (0..24).map(|i| (i % 5) as i32 - 2).collect()).into();
    let l2 = g.push("fc2", IntOp::LinearInt { wq: wl2, bias_q: Some(vec![1, -1]) }, &[f2]);
    let add_rq = Requant { m: 1, d: 0, lo: i64::MIN, hi: i64::MAX };
    g.push("add", IntOp::AddRequant { rqs: vec![add_rq] }, &[l1, l2]);

    let plan = IntPlan::compile(&g).unwrap();
    // conv has fanout 2 -> nothing fused into it.
    assert_eq!(plan.fused_nodes(), 0);
    let qx = Tensor::from_vec(
        &[2, 2, 4, 4],
        (0..64).map(|_| rng.int(0, 256) as i32).collect(),
    );
    check_int_plan(&g, &qx);
}

#[test]
fn threshold_epilogues_fuse_and_match() {
    // conv -> ThreshAct (no IntBn): threshold epilogue fuses into the
    // GEMM and matches the interpreter.
    let mut g = IntGraph::default();
    let spec = QuantSpec { eps: 1.0, lo: 0, hi: 15 };
    let x = g.push("in", IntOp::Input { shape: vec![1, 3, 3], spec }, &[]);
    let wq = Tensor::from_vec(&[9, 2], (0..18).map(|i| (i as i32 % 3) - 1).collect()).into();
    let conv = g.push(
        "conv",
        IntOp::ConvInt { wq, bias_q: None, cin: 1, kh: 3, kw: 3, stride: 1, pad: 1 },
        &[x],
    );
    let th = Thresholds {
        th: vec![vec![-5, 0, 5], vec![-2, 3, 8]],
        n_levels: 3,
    };
    g.push("act", IntOp::ThreshAct { th }, &[conv]);
    let plan = IntPlan::compile(&g).unwrap();
    assert_eq!(plan.fused_nodes(), 1);
    assert_eq!(plan.steps().len(), 2);
    let qx = Tensor::from_vec(&[2, 1, 3, 3], (0..18).map(|i| i % 16).collect());
    check_int_plan(&g, &qx);
}

#[test]
fn avgpool_flatten_linear_chain_matches() {
    // AvgPoolInt -> IntBn (standalone) -> Flatten -> LinearInt+Requant.
    let mut g = IntGraph::default();
    let spec = QuantSpec { eps: 1.0, lo: 0, hi: 255 };
    let x = g.push("in", IntOp::Input { shape: vec![2, 4, 4], spec }, &[]);
    let p = g.push("ap", IntOp::AvgPoolInt { k: 2, d: 12 }, &[x]);
    let bn = BnQuant {
        kappa_q: vec![3, -2],
        lambda_q: vec![-1, 4],
        eps_kappa: 0.01,
        eps_phi_out: 0.001,
    };
    let b = g.push("bn", IntOp::IntBn { bn }, &[p]);
    let f = g.push("fl", IntOp::Flatten, &[b]);
    let wq = Tensor::from_vec(&[8, 3], (0..24).map(|i| (i % 9) as i32 - 4).collect()).into();
    let l = g.push("fc", IntOp::LinearInt { wq, bias_q: Some(vec![10, -10, 0]) }, &[f]);
    let rq = Requant { m: 9, d: 4, lo: 0, hi: 255 };
    g.push("act", IntOp::RequantAct { rq }, &[l]);

    let plan = IntPlan::compile(&g).unwrap();
    assert_eq!(plan.fused_nodes(), 1); // requant into the linear
    let mut rng = Rng::new(11);
    let qx = Tensor::from_vec(
        &[3, 2, 4, 4],
        (0..96).map(|_| rng.int(0, 256) as i32).collect(),
    );
    check_int_plan(&g, &qx);
}
