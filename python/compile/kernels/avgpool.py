"""Integer average-pooling Pallas kernel (Eq. 25).

    Q(p) = (floor(2^d / (K1*K2)) * sum_window Q(t)) >> d

Window = stride (non-overlapping), the layout used by the paper's target
networks (global average pooling heads). One grid step processes one
(batch, channel-tile) slab, summing the window by an in-VMEM reshape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INT, WIDE, INTERPRET, cdiv, pad_to


def _avgpool_kernel(q_ref, o_ref, *, k1: int, k2: int, d: int):
    q = q_ref[...]                      # [1, bc, H, W]
    _, bc, h, w = q.shape
    r = q.reshape(1, bc, h // k1, k1, w // k2, k2).astype(WIDE)
    acc = jnp.sum(r, axis=(3, 5))
    m = (1 << d) // (k1 * k2)
    o_ref[...] = jnp.right_shift(acc * WIDE(m), WIDE(d)).astype(INT)


def avgpool(q: jnp.ndarray, k1: int, k2: int, d: int, *, bc: int = 16) -> jnp.ndarray:
    """q: [B, C, H, W] int32 with H % k1 == 0 and W % k2 == 0."""
    b, c, h, w = q.shape
    assert h % k1 == 0 and w % k2 == 0, "window must tile the input"
    qp = pad_to(q, 1, bc)
    out = pl.pallas_call(
        functools.partial(_avgpool_kernel, k1=k1, k2=k2, d=d),
        grid=(b, cdiv(c, bc)),
        in_specs=[pl.BlockSpec((1, bc, h, w), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, bc, h // k1, w // k2), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, qp.shape[1], h // k1, w // k2), INT),
        interpret=INTERPRET,
    )(qp)
    return out[:, :c]
