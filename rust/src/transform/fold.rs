//! BN folding (Eq. 18) and input-bias translation (sec. 3.7).

use super::TransformError;
use crate::graph::{Graph, NodeId, Op};

/// Fold every BatchNorm into its preceding Linear operator (Eq. 18):
///
///   w <- gamma/sigma * w ;  b <- b + beta - gamma/sigma * mu
///
/// `only` optionally restricts folding to the named BN nodes (NEMO's
/// optional dictionary argument). After folding, weight clipping bounds
/// must be re-derived (NEMO's `reset_alpha_weights`) — that happens
/// naturally here because `quantize_pact`/`deploy` recompute beta_w from
/// the folded weights.
/// Crate-private: the public entry point is `network::Network::fold_bn`,
/// which tracks the fold so it cannot corrupt weights by running twice.
pub(crate) fn fold_bn_impl(
    g: &Graph,
    only: Option<&[&str]>,
) -> Result<Graph, TransformError> {
    g.validate()?;
    let fanout = g.fanout();
    // Which BN nodes to fold: preceded by a Linear op with fanout 1.
    let mut fold_into: Vec<Option<NodeId>> = vec![None; g.nodes.len()];
    for n in &g.nodes {
        if let Op::BatchNorm { .. } = n.op {
            if let Some(name_filter) = only {
                if !name_filter.contains(&n.name.as_str()) {
                    continue;
                }
            }
            let prev = n.inputs[0];
            if g.nodes[prev].op.is_linear() && fanout[prev] == 1 {
                fold_into[n.id] = Some(prev);
            }
        }
    }

    // Rebuild the graph without the folded BN nodes.
    let mut out = Graph::new(g.eps_in);
    let mut remap: Vec<NodeId> = vec![usize::MAX; g.nodes.len()];
    for n in &g.nodes {
        if let Some(linear_id) = fold_into[n.id] {
            // Skip the BN node; its effect lands on the linear's weights.
            remap[n.id] = remap[linear_id];
            continue;
        }
        let mut op = n.op.clone();
        // If some BN folds into *this* linear node, transform its params.
        if n.op.is_linear() {
            if let Some(bn_id) = fold_into
                .iter()
                .position(|f| *f == Some(n.id))
            {
                if let Op::BatchNorm { bn } = &g.nodes[bn_id].op {
                    let (kappa, lambda) = bn.fold();
                    match &mut op {
                        Op::Conv2d { w, bias, .. } => {
                            let co = w.shape()[0];
                            let per: usize = w.shape()[1..].iter().product();
                            for oc in 0..co {
                                let k = kappa[oc] as f32;
                                for v in &mut w.data_mut()[oc * per..(oc + 1) * per] {
                                    *v *= k;
                                }
                            }
                            let mut b = bias.clone().unwrap_or_else(|| vec![0.0; co]);
                            for oc in 0..co {
                                b[oc] += lambda[oc];
                            }
                            *bias = Some(b);
                        }
                        Op::Linear { w, bias } => {
                            // weights [in, out]: scale per output column
                            let (fi, fo) = (w.shape()[0], w.shape()[1]);
                            for i in 0..fi {
                                for o in 0..fo {
                                    w.data_mut()[i * fo + o] *= kappa[o] as f32;
                                }
                            }
                            let mut b = bias.clone().unwrap_or_else(|| vec![0.0; fo]);
                            for o in 0..fo {
                                b[o] += lambda[o];
                            }
                            *bias = Some(b);
                        }
                        _ => unreachable!(),
                    }
                }
            }
        }
        let inputs: Vec<NodeId> = n.inputs.iter().map(|&i| remap[i]).collect();
        remap[n.id] = out.push(&n.name, op, &inputs);
    }
    out.output = remap[g.output];
    Ok(out)
}

/// Input representation translation (sec. 3.7): when the "natural" input
/// has offset alpha != 0 (t = alpha + eps*Q), rewrite the first Linear
/// node so the network consumes the canonical [0, beta) image:
///
///   phi = <w, alpha + x_hat> = <w, x_hat> + alpha * sum(w)
///
/// Exact for fully-connected first layers and for convolutions without
/// zero padding (padding would inject canonical zeros that should have
/// been alpha).
pub fn add_input_bias(g: &Graph, alpha: f64) -> Result<Graph, TransformError> {
    if alpha == 0.0 {
        return Ok(g.clone());
    }
    let mut out = g.clone();
    // first Linear consumer of the Input node
    let input_id = out
        .nodes
        .iter()
        .position(|n| matches!(n.op, Op::Input { .. }))
        .ok_or_else(|| TransformError::InputBias("no input node".into()))?;
    let first_linear = out
        .nodes
        .iter()
        .position(|n| n.inputs.contains(&input_id) && n.op.is_linear())
        .ok_or_else(|| {
            TransformError::InputBias("input is not consumed by a Linear node".into())
        })?;
    match &mut out.nodes[first_linear].op {
        Op::Conv2d { w, bias, pad, .. } => {
            if *pad != 0 {
                return Err(TransformError::InputBias(
                    "conv with zero padding cannot absorb an input offset exactly"
                        .into(),
                ));
            }
            let co = w.shape()[0];
            let per: usize = w.shape()[1..].iter().product();
            let mut b = bias.clone().unwrap_or_else(|| vec![0.0; co]);
            for oc in 0..co {
                let s: f64 = w.data()[oc * per..(oc + 1) * per]
                    .iter()
                    .map(|v| *v as f64)
                    .sum();
                b[oc] += alpha * s;
            }
            *bias = Some(b);
        }
        Op::Linear { w, bias } => {
            let (fi, fo) = (w.shape()[0], w.shape()[1]);
            let mut b = bias.clone().unwrap_or_else(|| vec![0.0; fo]);
            for o in 0..fo {
                let mut s = 0f64;
                for i in 0..fi {
                    s += w.data()[i * fo + o] as f64;
                }
                b[o] += alpha * s;
            }
            *bias = Some(b);
        }
        _ => unreachable!(),
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FloatEngine;
    use crate::quant::bn::BnParams;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn conv_bn_relu_graph(rng: &mut Rng) -> Graph {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![2, 6, 6] }, &[]);
        let w = Tensor::from_vec(
            &[3, 2, 3, 3],
            (0..54).map(|_| rng.normal(0.0, 0.4) as f32).collect(),
        );
        let c = g.push("conv", Op::Conv2d { w, bias: None, stride: 1, pad: 1 }, &[x]);
        let bn = BnParams {
            gamma: (0..3).map(|_| rng.uniform(0.2, 2.0)).collect(),
            sigma: (0..3).map(|_| rng.uniform(0.2, 2.0)).collect(),
            beta: (0..3).map(|_| rng.normal(0.0, 0.3)).collect(),
            mu: (0..3).map(|_| rng.normal(0.0, 0.3)).collect(),
        };
        let b = g.push("bn", Op::BatchNorm { bn }, &[c]);
        g.push("act", Op::ReLU, &[b]);
        g
    }

    #[test]
    fn fold_bn_preserves_function() {
        let mut rng = Rng::new(42);
        let g = conv_bn_relu_graph(&mut rng);
        let folded = fold_bn_impl(&g, None).unwrap();
        assert_eq!(folded.nodes.len(), g.nodes.len() - 1);
        let x = Tensor::from_vec(
            &[2, 2, 6, 6],
            (0..144).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        );
        let e = FloatEngine::new();
        let a = e.run(&g, &x);
        let b = e.run(&folded, &x);
        assert!(a.allclose(&b, 1e-4, 1e-4), "max diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn fold_bn_respects_name_filter() {
        let mut rng = Rng::new(1);
        let g = conv_bn_relu_graph(&mut rng);
        let kept = fold_bn_impl(&g, Some(&["other"])).unwrap();
        assert_eq!(kept.nodes.len(), g.nodes.len()); // nothing folded
    }

    #[test]
    fn input_bias_translates_offset() {
        // network over t = alpha + x_hat must equal rewritten network
        // over x_hat alone.
        let mut rng = Rng::new(2);
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![4] }, &[]);
        let w = Tensor::from_vec(
            &[4, 3],
            (0..12).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
        );
        g.push("fc", Op::Linear { w, bias: Some(vec![0.1, 0.2, 0.3]) }, &[x]);

        let alpha = -0.5f64;
        let g2 = add_input_bias(&g, alpha).unwrap();
        let e = FloatEngine::new();
        let xhat = Tensor::from_vec(&[1, 4], vec![0.1f32, 0.9, 0.4, 0.7]);
        let xoff = xhat.map(|v| v + alpha as f32);
        let want = e.run(&g, &xoff);
        let got = e.run(&g2, &xhat);
        assert!(want.allclose(&got, 1e-5, 1e-5));
    }

    #[test]
    fn input_bias_rejects_padded_conv() {
        let mut rng = Rng::new(3);
        let g = conv_bn_relu_graph(&mut rng); // pad = 1
        assert!(add_input_bias(&g, -0.5).is_err());
    }
}
