//! Interval abstract domain over [`IntGraph`].
//!
//! One forward pass propagates a sound `[lo, hi]` over-approximation of
//! every node's runtime values, seeded from the input `QuantSpec` grid
//! and the *actual* weight/bias magnitudes (not worst-case precision
//! classes). The graph is a DAG in topological order (`IntGraph::push`
//! asserts forward references), so a single pass with no widening is
//! exact for this domain.
//!
//! All arithmetic runs in `i128` and saturates into `i64` at the
//! interval boundary, so adversarial weights cannot overflow the
//! analysis itself — the rules in [`super`] then compare the intervals
//! against the `i32` datapath the integer engine actually executes.

use crate::graph::int::{IntGraph, IntOp};
use crate::quant::bn::{BnQuant, Thresholds};
use crate::quant::requant::Requant;
use crate::tensor::QTensor;

/// Inclusive integer interval. `lo <= hi` by construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    pub lo: i64,
    pub hi: i64,
}

impl Interval {
    pub fn new(lo: i64, hi: i64) -> Interval {
        debug_assert!(lo <= hi, "interval [{lo}, {hi}] is inverted");
        Interval { lo, hi }
    }

    /// Interval spanning two (unordered) endpoint images.
    pub fn of_endpoints(a: i64, b: i64) -> Interval {
        Interval { lo: a.min(b), hi: a.max(b) }
    }

    pub fn contains(&self, v: i64) -> bool {
        self.lo <= v && v <= self.hi
    }

    /// Does every value in the interval fit the i32 datapath?
    pub fn fits_i32(&self) -> bool {
        self.lo >= i32::MIN as i64 && self.hi <= i32::MAX as i64
    }

    /// Largest absolute value reachable in the interval.
    pub fn max_abs(&self) -> i64 {
        self.lo.saturating_abs().max(self.hi.saturating_abs())
    }

    /// Extend to include a value (conv zero-padding injects 0s).
    fn including(self, v: i64) -> Interval {
        Interval { lo: self.lo.min(v), hi: self.hi.max(v) }
    }
}

fn sat64(v: i128) -> i64 {
    v.clamp(i64::MIN as i128, i64::MAX as i128) as i64
}

/// Worst-case GEMM accumulator interval over input interval `x`: per
/// output channel, sum per-weight extremes (the checker-side mirror of
/// deploy's range analysis, in i128 so huge adversarial weights
/// saturate instead of wrapping). Weight layout is the paper's Eq. 16
/// matrix `[rows, C_out]`.
pub(crate) fn gemm_range(wq: &QTensor, x: Interval, bias: Option<&[i64]>) -> Interval {
    let wide = wq.widen();
    let (rows, co) = (wide.shape()[0], wide.shape()[1]);
    let mut worst_lo = 0i128;
    let mut worst_hi = 0i128;
    for oc in 0..co {
        let mut lo = 0i128;
        let mut hi = 0i128;
        for r in 0..rows {
            let w = wide.at2(r, oc) as i128;
            let a = w * x.lo as i128;
            let b = w * x.hi as i128;
            lo += a.min(b);
            hi += a.max(b);
        }
        if let Some(bq) = bias {
            lo += bq[oc] as i128;
            hi += bq[oc] as i128;
        }
        worst_lo = worst_lo.min(lo);
        worst_hi = worst_hi.max(hi);
    }
    Interval { lo: sat64(worst_lo), hi: sat64(worst_hi) }
}

/// Per-channel BN image (Eq. 22): `kappa_q[c]*q + lambda_q[c]` is
/// monotone per channel, so channel extremes at the input endpoints
/// bound the whole tensor. Tighter than a symmetric `|kappa|max*|q|max`
/// bound and still sound.
fn bn_range(bn: &BnQuant, x: Interval) -> Interval {
    let mut lo = i128::MAX;
    let mut hi = i128::MIN;
    for c in 0..bn.kappa_q.len() {
        let k = bn.kappa_q[c] as i128;
        let l = bn.lambda_q[c] as i128;
        let a = k * x.lo as i128 + l;
        let b = k * x.hi as i128 + l;
        lo = lo.min(a.min(b));
        hi = hi.max(a.max(b));
    }
    if lo > hi {
        // no channels: identity-free degenerate op, keep the input range
        return x;
    }
    Interval { lo: sat64(lo), hi: sat64(hi) }
}

/// Requant image (Eq. 11): `clip((m*q) >> d, lo, hi)` is monotone in q
/// for fixed m (non-increasing when m < 0), so the two endpoint images
/// bound the interval exactly.
pub(crate) fn requant_range(rq: &Requant, x: Interval) -> Interval {
    Interval::of_endpoints(rq.apply(x.lo), rq.apply(x.hi))
}

/// Pre-clip requant product `(m*q) >> d` in i128 — what the clamp in
/// [`Requant::apply`] would see. Used by the saturation rule to prove
/// the clip never engages on pure-rescale requants.
pub(crate) fn requant_preclip(rq: &Requant, x: Interval) -> (i128, i128) {
    let a = (rq.m as i128 * x.lo as i128) >> rq.d;
    let b = (rq.m as i128 * x.hi as i128) >> rq.d;
    (a.min(b), a.max(b))
}

/// Threshold-activation image (Eq. 19-20): the count of thresholds
/// `<= q` is monotone in q per channel, so channel extremes at the
/// input endpoints bound the output.
fn thresh_range(th: &Thresholds, x: Interval) -> Interval {
    if th.th.is_empty() {
        return Interval::new(0, 0);
    }
    let mut lo = i64::MAX;
    let mut hi = i64::MIN;
    for c in 0..th.th.len() {
        lo = lo.min(th.apply(c, x.lo));
        hi = hi.max(th.apply(c, x.hi));
    }
    Interval { lo, hi }
}

/// Average-pool image (Eq. 25): the kernel sums k*k inputs into an i64
/// accumulator and rescales `(m*acc) >> d` with `m = 2^d / k^2`. The
/// rescale is monotone in acc (m >= 0), so the endpoint accumulators
/// `k^2*lo` / `k^2*hi` bound the output.
fn avgpool_range(k: usize, d: u32, x: Interval) -> Interval {
    let k2 = (k * k) as i128;
    if k2 == 0 {
        return x;
    }
    let m = (1i128 << d.min(126)) / k2;
    let a = sat64((m * k2 * x.lo as i128) >> d);
    let b = sat64((m * k2 * x.hi as i128) >> d);
    Interval::of_endpoints(a, b)
}

/// Add-with-requant image (Eq. 24): branch 0 is the reference space;
/// each further branch contributes its requantized interval to the sum.
/// Summation in i128, saturated into i64.
fn add_range(intervals: &[Interval], inputs: &[usize], rqs: &[Requant]) -> Interval {
    let rf = intervals[inputs[0]];
    let mut lo = rf.lo as i128;
    let mut hi = rf.hi as i128;
    for (i, rq) in rqs.iter().enumerate() {
        let b = requant_range(rq, intervals[inputs[i + 1]]);
        lo += b.lo as i128;
        hi += b.hi as i128;
    }
    Interval { lo: sat64(lo), hi: sat64(hi) }
}

/// One forward abstract-interpretation pass. Returns one interval per
/// node, indexed by node id. Call only on a graph that passed
/// [`IntGraph::validate`] — input ids are assumed in bounds and
/// backward-pointing.
pub fn infer_intervals(g: &IntGraph) -> Vec<Interval> {
    let mut out: Vec<Interval> = Vec::with_capacity(g.nodes.len());
    for nd in &g.nodes {
        let in0 = nd.inputs.first().map(|&i| out[i]);
        let iv = match &nd.op {
            IntOp::Input { spec, .. } => Interval::new(spec.lo.min(spec.hi), spec.hi.max(spec.lo)),
            IntOp::ConvInt { wq, bias_q, pad, .. } => {
                // zero padding injects 0s into the conv's input window
                let mut x = in0.expect("conv has an input");
                if *pad > 0 {
                    x = x.including(0);
                }
                gemm_range(wq, x, bias_q.as_deref())
            }
            IntOp::LinearInt { wq, bias_q } => {
                gemm_range(wq, in0.expect("linear has an input"), bias_q.as_deref())
            }
            IntOp::IntBn { bn } => bn_range(bn, in0.expect("bn has an input")),
            IntOp::RequantAct { rq } => requant_range(rq, in0.expect("requant has an input")),
            IntOp::ThreshAct { th } => thresh_range(th, in0.expect("thresh has an input")),
            IntOp::AvgPoolInt { k, d } => avgpool_range(*k, *d, in0.expect("pool has an input")),
            IntOp::MaxPoolInt { .. } | IntOp::Flatten => in0.expect("op has an input"),
            IntOp::AddRequant { rqs } => add_range(&out, &nd.inputs, rqs),
        };
        out.push(iv);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorI;

    fn rq(m: i64, d: u32, lo: i64, hi: i64) -> Requant {
        Requant { m, d, lo, hi }
    }

    #[test]
    fn endpoint_interval_is_unordered_safe() {
        let iv = Interval::of_endpoints(5, -3);
        assert_eq!((iv.lo, iv.hi), (-3, 5));
        assert!(iv.contains(0) && !iv.contains(6));
        assert_eq!(iv.max_abs(), 5);
    }

    #[test]
    fn gemm_range_matches_hand_computation() {
        // weights [[2], [-3]] over x in [0, 10]: lo = -30, hi = 20
        let w = TensorI::from_vec(&[2, 1], vec![2, -3]);
        let iv = gemm_range(&QTensor::I32(w), Interval::new(0, 10), Some(&[5]));
        assert_eq!((iv.lo, iv.hi), (-25, 25));
    }

    #[test]
    fn gemm_range_saturates_instead_of_wrapping() {
        let w = TensorI::from_vec(&[4, 1], vec![i32::MAX; 4]);
        let iv = gemm_range(&QTensor::I32(w), Interval::new(i64::MIN / 2, i64::MAX / 2), None);
        assert_eq!((iv.lo, iv.hi), (i64::MIN, i64::MAX));
    }

    #[test]
    fn requant_range_is_sound_for_negative_multipliers() {
        // m < 0 flips monotonicity; endpoints must still bound the image
        let r = rq(-3, 1, i64::MIN, i64::MAX);
        let iv = requant_range(&r, Interval::new(-4, 10));
        for q in -4..=10 {
            assert!(iv.contains(r.apply(q)), "q={q} escaped {iv:?}");
        }
    }

    #[test]
    fn avgpool_range_brackets_the_kernel_arithmetic() {
        // k=2, d=8: m = 256/4 = 64; acc in [4*lo, 4*hi]
        let iv = avgpool_range(2, 8, Interval::new(-7, 13));
        let m = 64i64;
        assert_eq!(iv.lo, (m * 4 * -7) >> 8);
        assert_eq!(iv.hi, (m * 4 * 13) >> 8);
    }

    #[test]
    fn preclip_sees_through_the_clamp() {
        let r = rq(1 << 20, 0, i64::MIN, i64::MAX);
        let (lo, hi) = requant_preclip(&r, Interval::new(0, 1 << 20));
        assert_eq!(lo, 0);
        assert_eq!(hi, 1i128 << 40);
    }
}
