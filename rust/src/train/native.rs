//! Native training engine (DESIGN.md §Training): minibatch SGD driven by
//! the backward-plan compiler — `nemo train` with no PJRT runtime and no
//! Python-authored artifact.
//!
//! Per step: scatter the f64 masters into the graph (the FQ path writes
//! fake-quantized weight copies instead — the weight straight-through
//! estimator), run the unfused forward plan with activation
//! checkpointing, seed the backward plan with the softmax cross-entropy
//! gradient, and step the masters with SGD (momentum + weight decay).
//! The forward plan is recompiled each step because it bakes weights
//! into GEMM-ready matrices; the backward plan and both layouts are
//! compiled once per run, and one shared [`FloatArena`] serves forward
//! and backward (its slot pool only ever grows).

use anyhow::{Context, Result};

use crate::data::SynthDigits;
use crate::engine::{BackwardPlan, FloatArena, FloatPlan};
use crate::graph::grad::{self, ParamKind, ParamRef};
use crate::graph::{Graph, Op};
use crate::io::Checkpoint;
use crate::model::synthnet::SynthNet;
use crate::quant::QuantSpec;
use crate::tensor::{Tensor, TensorF};

use super::{effective_lr, TrainConfig, TrainReport};

/// Floor for trained PACT clips: a non-positive β would degenerate the
/// activation grid (eps ≤ 0), so clips are clamped here after each step.
pub const PACT_BETA_MIN: f64 = 1e-3;

/// SGD momentum buffer + step counter, aligned with the flat master
/// vector. Persisted inside the model checkpoint under the `opt.*` keys
/// so an interrupted run resumes with momentum intact; a checkpoint
/// without them (pre-training, or written by an older build) loads as a
/// fresh optimizer.
#[derive(Clone, Debug, Default)]
pub struct OptState {
    pub v: Vec<f64>,
    /// Optimizer steps taken across all resumed legs.
    pub step: usize,
}

impl OptState {
    /// Store alongside the model keys of a checkpoint.
    pub fn save(&self, ck: &mut Checkpoint) {
        ck.insert_f64("opt.v", &[self.v.len()], self.v.clone());
        ck.insert_f64("opt.step", &[1], vec![self.step as f64]);
    }

    /// Restore from a checkpoint; fresh state if the keys are absent.
    pub fn load(ck: &Checkpoint) -> OptState {
        let v = ck.get_f64("opt.v").map(|(_, d)| d.to_vec()).unwrap_or_default();
        let step = ck.get_f64("opt.step").map(|(_, d)| d[0] as usize).unwrap_or(0);
        OptState { v, step }
    }
}

/// One SGD step over the flat masters:
/// v ← μ·v + g + wd·θ (decay only where `decay[i]`), θ ← θ − lr·v.
/// The velocity buffer is (re)zeroed when its length does not match θ —
/// e.g. when an FP leg hands its state to an FQ leg, whose PACT clips
/// change the parameter count.
pub fn sgd_step(
    theta: &mut [f64],
    gtheta: &[f64],
    state: &mut OptState,
    lr: f64,
    momentum: f64,
    weight_decay: f64,
    decay: &[bool],
) {
    assert_eq!(theta.len(), gtheta.len(), "gradient/parameter length mismatch");
    if state.v.len() != theta.len() {
        state.v = vec![0.0; theta.len()];
    }
    for (i, (t, &g)) in theta.iter_mut().zip(gtheta).enumerate() {
        let wd = if decay[i] { weight_decay * *t } else { 0.0 };
        let v = momentum * state.v[i] + g + wd;
        state.v[i] = v;
        *t -= lr * v;
    }
    state.step += 1;
}

/// Per-element weight-decay mask over the flat layout: decay
/// conv/linear weights only — the standard exemption for biases, BN
/// affine parameters, and PACT clips.
pub fn decay_mask(refs: &[ParamRef]) -> Vec<bool> {
    let mut m = Vec::with_capacity(grad::param_len(refs));
    for r in refs {
        let is_w = matches!(r.kind, ParamKind::Weight);
        for _ in 0..r.len {
            m.push(is_w);
        }
    }
    m
}

/// Mean softmax cross-entropy over a `[B, C]` logit batch and its seed
/// gradient dL/dlogits = (softmax − onehot)/B, computed in f64 with the
/// usual max-shift for stability.
pub fn softmax_xent(logits: &TensorF, labels: &[usize]) -> (f64, TensorF) {
    let (b, c) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(labels.len(), b, "label count != batch size");
    let mut seed = vec![0f32; b * c];
    let mut loss = 0.0;
    for (i, &label) in labels.iter().enumerate() {
        let row = &logits.data()[i * c..(i + 1) * c];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
        let mut z = 0.0;
        for &v in row {
            z += (v as f64 - max).exp();
        }
        loss += z.ln() - (row[label] as f64 - max);
        for (j, &v) in row.iter().enumerate() {
            let p = (v as f64 - max).exp() / z;
            let onehot = if j == label { 1.0 } else { 0.0 };
            seed[i * c + j] = ((p - onehot) / b as f64) as f32;
        }
    }
    (loss / b as f64, Tensor::from_vec(&[b, c], seed))
}

/// Write masters into the graph. In FQ mode (`wbits = Some`),
/// conv/linear weights go in as their fake-quantized copies on the
/// symmetric grid β_w = max|w| (NEMO's reset_alpha_weights statistic) —
/// quantized forward, gradients applied to the float masters (STE).
fn write_params(g: &mut Graph, refs: &[ParamRef], theta: &[f64], wbits: Option<u32>) {
    let mut off = 0;
    for &r in refs {
        let vals = &theta[off..off + r.len];
        match (wbits, r.kind) {
            (Some(bits), ParamKind::Weight) => {
                let beta = vals.iter().fold(0.0f64, |m, v| m.max(v.abs()));
                let spec = QuantSpec::weight(if beta == 0.0 { 1.0 } else { beta }, bits);
                let fq: Vec<f64> = vals.iter().map(|&v| spec.fake_quantize(v)).collect();
                grad::set_param(g, r, &fq);
            }
            _ => grad::set_param(g, r, vals),
        }
        off += r.len;
    }
}

fn clamp_pact(refs: &[ParamRef], theta: &mut [f64]) {
    let mut off = 0;
    for r in refs {
        if matches!(r.kind, ParamKind::PactBeta) && theta[off] < PACT_BETA_MIN {
            theta[off] = PACT_BETA_MIN;
        }
        off += r.len;
    }
}

/// Minibatch-SGD a float graph in place. On return the graph holds the
/// final *masters* (never their quantized copies) — what a checkpoint
/// should persist; deployment re-derives the weight grids itself.
pub fn train_graph(
    g: &mut Graph,
    data: &mut SynthDigits,
    cfg: &TrainConfig,
    wbits: Option<u32>,
    opt: &mut OptState,
    tag: &str,
) -> Result<TrainReport> {
    let refs = grad::param_refs(g);
    let mut theta = grad::gather_params(g, &refs);
    let decay = decay_mask(&refs);
    let bwd = BackwardPlan::compile(g).context("compiling backward plan")?;
    let blayout = bwd.layout(g, cfg.batch).context("backward layout")?;
    let mut arena = FloatArena::new();
    let mut report = TrainReport::default();
    for step in 0..cfg.steps {
        write_params(g, &refs, &theta, wbits);
        let fwd = FloatPlan::compile_unfused(g).context("compiling forward plan")?;
        let flayout = fwd.layout(cfg.batch)?;
        let (x, labels) = data.batch(cfg.batch);
        let (logits, tape) =
            fwd.execute_checkpointed(&flayout, &mut arena, &x, bwd.tape_mask());
        let (loss, seed) = softmax_xent(&logits, &labels);
        let grads = bwd.execute(g, &blayout, &mut arena, &tape, &seed);
        let lr = effective_lr(cfg, step);
        sgd_step(
            &mut theta,
            &grads.gather(&refs),
            opt,
            lr,
            cfg.momentum,
            cfg.weight_decay,
            &decay,
        );
        clamp_pact(&refs, &mut theta);
        report.losses.push(loss);
        report.steps += 1;
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            eprintln!("[{tag} step {step:4}] loss = {loss:.4} lr = {lr:.4}");
        }
    }
    grad::scatter_params(g, &refs, &theta);
    Ok(report)
}

/// Read trained parameters back from a graph built by
/// [`SynthNet::to_graph`] into the net's fields. BN running stats (μ, σ²)
/// are frozen during native training and stay untouched.
fn read_back(net: &mut SynthNet, g: &Graph) {
    let (mut ci, mut bi, mut ai) = (0usize, 0usize, 0usize);
    for nd in &g.nodes {
        match &nd.op {
            Op::Conv2d { w, .. } => {
                net.convs[ci].0 = w.clone();
                ci += 1;
            }
            Op::BatchNorm { bn } => {
                net.convs[bi].1 = bn.gamma.clone();
                net.convs[bi].2 = bn.beta.clone();
                bi += 1;
            }
            Op::PactAct { beta, .. } => {
                net.act_betas[ai] = *beta;
                ai += 1;
            }
            Op::Linear { w, bias } => {
                net.fc_w = w.clone();
                if let Some(b) = bias {
                    net.fc_b = b.clone();
                }
            }
            _ => {}
        }
    }
}

/// Native FullPrecision training (ReLU graph) — the no-PJRT counterpart
/// of the artifact-driven `train_fp`.
pub fn train_fp(
    net: &mut SynthNet,
    data: &mut SynthDigits,
    cfg: &TrainConfig,
    opt: &mut OptState,
) -> Result<TrainReport> {
    let mut g = net.to_fp_graph();
    let report = train_graph(&mut g, data, cfg, None, opt, "fp ")?;
    read_back(net, &g);
    Ok(report)
}

/// Native QAT fine-tune (paper sec. 2.2): PACT activations at `abits`
/// with learned clips, weights straight-through-estimated at `wbits`.
pub fn train_fq(
    net: &mut SynthNet,
    data: &mut SynthDigits,
    wbits: u32,
    abits: u32,
    cfg: &TrainConfig,
    opt: &mut OptState,
) -> Result<TrainReport> {
    let mut g = net.to_pact_graph(abits);
    let report = train_graph(&mut g, data, cfg, Some(wbits), opt, &format!("fq{wbits}"))?;
    read_back(net, &g);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sgd_step_matches_hand_calc() {
        let mut theta = vec![1.0, 2.0];
        let mut st = OptState::default();
        let decay = vec![true, false];
        sgd_step(&mut theta, &[0.5, -1.0], &mut st, 0.1, 0.9, 0.01, &decay);
        // v0 = 0.5 + 0.01*1.0 = 0.51; v1 = -1.0 (no decay)
        assert!((theta[0] - (1.0 - 0.1 * 0.51)).abs() < 1e-12);
        assert!((theta[1] - (2.0 + 0.1)).abs() < 1e-12);
        sgd_step(&mut theta, &[0.0, 0.0], &mut st, 0.1, 0.9, 0.0, &decay);
        // pure momentum carry: v *= 0.9
        assert!((st.v[0] - 0.9 * 0.51).abs() < 1e-12);
        assert_eq!(st.step, 2);
    }

    #[test]
    fn softmax_xent_uniform_and_onehot() {
        let logits = Tensor::from_vec(&[1, 2], vec![0.0, 0.0]);
        let (loss, seed) = softmax_xent(&logits, &[0]);
        assert!((loss - (2f64).ln()).abs() < 1e-6);
        assert!((seed.data()[0] + 0.5).abs() < 1e-6);
        assert!((seed.data()[1] - 0.5).abs() < 1e-6);
        // seed rows always sum to zero (softmax sums to 1)
        let logits = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 0.5, 3.0, 3.0, -1.0]);
        let (_, seed) = softmax_xent(&logits, &[2, 0]);
        for i in 0..2 {
            let s: f32 = seed.data()[i * 3..(i + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "row {i} seed sums to {s}");
        }
    }

    #[test]
    fn decay_mask_marks_weights_only() {
        let mut rng = Rng::new(5);
        let net = SynthNet::init(&mut rng);
        let g = net.to_pact_graph(8);
        let refs = grad::param_refs(&g);
        let mask = decay_mask(&refs);
        assert_eq!(mask.len(), grad::param_len(&refs));
        let mut off = 0;
        for r in &refs {
            let is_w = matches!(r.kind, ParamKind::Weight);
            for &m in &mask[off..off + r.len] {
                assert_eq!(m, is_w);
            }
            off += r.len;
        }
    }

    #[test]
    fn opt_state_roundtrips_through_checkpoint() {
        let mut ck = Checkpoint::default();
        let st = OptState { v: vec![0.25, -1.5, 3.0], step: 17 };
        st.save(&mut ck);
        let back = OptState::load(&ck);
        assert_eq!(back.v, st.v);
        assert_eq!(back.step, 17);
        // missing keys -> fresh optimizer
        let fresh = OptState::load(&Checkpoint::default());
        assert!(fresh.v.is_empty());
        assert_eq!(fresh.step, 0);
    }

    #[test]
    fn native_fp_training_reduces_loss() {
        let mut rng = Rng::new(41);
        let mut net = SynthNet::init(&mut rng);
        let mut data = SynthDigits::new(41);
        let cfg = TrainConfig {
            steps: 30,
            lr: 0.1,
            lr_decay: false,
            seed: 41,
            log_every: 0,
            batch: 16,
            ..TrainConfig::default()
        };
        let mut opt = OptState::default();
        let rep = train_fp(&mut net, &mut data, &cfg, &mut opt).unwrap();
        let (head, tail) = rep.head_tail(5);
        assert!(tail < head, "native FP loss did not decrease: {head:.3} -> {tail:.3}");
        assert_eq!(opt.step, 30);
    }

    #[test]
    fn native_fq_trains_clips_and_keeps_float_masters() {
        let mut rng = Rng::new(42);
        let mut net = SynthNet::init(&mut rng);
        // sane clips to start from (init betas may be arbitrary)
        net.act_betas = vec![4.0, 4.0, 4.0];
        let mut data = SynthDigits::new(42);
        let betas_before = net.act_betas.clone();
        let cfg = TrainConfig {
            steps: 20,
            lr: 0.05,
            lr_decay: false,
            seed: 42,
            log_every: 0,
            batch: 16,
            ..TrainConfig::default()
        };
        let mut opt = OptState::default();
        let rep = train_fq(&mut net, &mut data, 4, 4, &cfg, &mut opt).unwrap();
        assert!(rep.final_loss().is_finite());
        assert_ne!(betas_before, net.act_betas, "PACT clips were not trained");
        // masters stay off the 4-bit grid: with beta = max|w| the grid
        // has 16 points; 72 conv-1 weights all landing on it exactly
        // would mean we stored the hardened copies by mistake.
        let w = &net.convs[0].0;
        let beta = crate::quant::max_abs(w);
        let spec = QuantSpec::weight(beta, 4);
        let off_grid = w
            .data()
            .iter()
            .filter(|&&v| (v as f64 - spec.fake_quantize(v as f64)).abs() > 1e-9)
            .count();
        assert!(off_grid > 0, "trained weights collapsed onto the quantized grid");
    }
}
