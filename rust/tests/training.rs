//! Training-driver integration tests: the PJRT-compiled FP and FQ (QAT)
//! train steps must actually learn, and training must be deterministic.
//! Requires the `pjrt` feature and artifacts (skips otherwise).
#![cfg(feature = "pjrt")]

use nemo::data::SynthDigits;
use nemo::io::artifacts_dir;
use nemo::model::synthnet::SynthNet;
use nemo::runtime::Runtime;
use nemo::train::{train_fp, train_fq, TrainConfig};
use nemo::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Runtime::new(dir).unwrap())
}

#[test]
fn fp_training_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(41);
    let mut net = SynthNet::init(&mut rng);
    let mut data = SynthDigits::new(41);
    let cfg = TrainConfig {
        steps: 60,
        lr: 0.2,
        lr_decay: false,
        seed: 41,
        log_every: 0,
        ..TrainConfig::default()
    };
    let rep = train_fp(&rt, &mut net, &mut data, &cfg).unwrap();
    let (head, tail) = rep.head_tail(10);
    assert!(
        tail < head - 0.1,
        "FP loss did not decrease: {head:.3} -> {tail:.3}"
    );
    // BN running stats actually moved away from init
    assert!(net.bn_state[0].0.iter().any(|m| m.abs() > 1e-3));
}

#[test]
fn fq_training_reduces_loss_and_updates_betas() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(42);
    let mut net = SynthNet::init(&mut rng);
    let mut data = SynthDigits::new(42);
    let betas_before = net.act_betas.clone();
    let cfg = TrainConfig {
        steps: 60,
        lr: 0.1,
        lr_decay: false,
        seed: 42,
        log_every: 0,
        ..TrainConfig::default()
    };
    let rep = train_fq(&rt, &mut net, &mut data, 4, 4, &cfg).unwrap();
    let (head, tail) = rep.head_tail(10);
    assert!(
        tail < head,
        "FQ loss did not decrease: {head:.3} -> {tail:.3}"
    );
    // PACT betas are trainable (sec. 2.2) — they must have moved
    assert_ne!(betas_before, net.act_betas, "act betas were not trained");
}

#[test]
fn training_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let run = || {
        let mut rng = Rng::new(43);
        let mut net = SynthNet::init(&mut rng);
        let mut data = SynthDigits::new(43);
        let cfg = TrainConfig {
            steps: 12,
            lr: 0.1,
            lr_decay: true,
            seed: 43,
            log_every: 0,
            ..TrainConfig::default()
        };
        let rep = train_fp(&rt, &mut net, &mut data, &cfg).unwrap();
        (rep.losses, net.fc_w.data().to_vec())
    };
    let (l1, w1) = run();
    let (l2, w2) = run();
    assert_eq!(l1, l2, "loss curves diverge across identical runs");
    assert_eq!(w1, w2, "weights diverge across identical runs");
}

#[test]
fn all_fq_bitwidth_artifacts_are_usable() {
    let Some(rt) = runtime() else { return };
    for (wb, ab) in [(8u32, 8u32), (4, 4), (2, 2)] {
        let mut rng = Rng::new(44);
        let mut net = SynthNet::init(&mut rng);
        let mut data = SynthDigits::new(44);
        let cfg = TrainConfig {
            steps: 3,
            lr: 0.05,
            lr_decay: false,
            seed: 44,
            log_every: 0,
            ..TrainConfig::default()
        };
        let rep = train_fq(&rt, &mut net, &mut data, wb, ab, &cfg).unwrap();
        assert!(rep.final_loss().is_finite(), "w{wb}a{ab} diverged");
    }
}
