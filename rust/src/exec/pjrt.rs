//! PJRT-backed [`Executor`]: serves the AOT-compiled IntegerDeployable
//! artifacts through the same interface as the native engines.
//!
//! Artifacts are lowered at several batch sizes (1/2/4/8/16); `run_batch`
//! picks the smallest compiled variant that fits, zero-pads the gathered
//! batch up to it, and slices the padding back off the outputs, so
//! callers see exactly the batch they submitted.

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::{Arg, ExecInput, ExecOutput, Executor};
use crate::runtime::{Executable, Runtime};
use crate::tensor::Tensor;

pub struct PjrtExecutor {
    /// (batch, executable), ascending by batch.
    variants: Vec<(usize, Arc<Executable>)>,
    /// The non-input arguments (integer deployment parameters).
    base_args: Vec<Arg>,
    /// Per-sample input shape (e.g. [1, 16, 16]).
    input_shape: Vec<usize>,
}

impl PjrtExecutor {
    /// Load every `kind` artifact (e.g. "id_fwd") from the runtime.
    pub fn load(rt: &Runtime, kind: &str, base_args: Vec<Arg>) -> Result<Self> {
        let specs = rt.manifest.by_kind(kind);
        ensure!(!specs.is_empty(), "no artifacts of kind '{kind}' in manifest");
        let mut variants = Vec::new();
        let mut input_shape = Vec::new();
        for s in specs {
            let b = s
                .batch
                .with_context(|| format!("artifact '{}' missing batch size", s.name))?;
            input_shape = s.sample_input_shape()?;
            variants.push((b, rt.load(&s.name)?));
        }
        variants.sort_by_key(|(b, _)| *b);
        Ok(PjrtExecutor { variants, base_args, input_shape })
    }

    /// Smallest compiled variant with batch >= n (largest otherwise).
    fn pick(&self, n: usize) -> &(usize, Arc<Executable>) {
        self.variants
            .iter()
            .find(|(b, _)| *b >= n)
            .unwrap_or_else(|| self.variants.last().unwrap())
    }
}

impl Executor for PjrtExecutor {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    fn max_batch(&self) -> usize {
        self.variants.last().map(|(b, _)| *b).unwrap_or(1)
    }

    fn effective_batch(&self, n: usize) -> usize {
        self.pick(n).0
    }

    fn run_batch(&self, input: &ExecInput) -> Result<ExecOutput> {
        let qx = input.batch.as_i32()?;
        let n =
            super::check_batch_shape("pjrt", qx.shape(), &self.input_shape, self.max_batch())?;
        let (batch, exe) = self.pick(n);
        // Zero-pad the gathered batch up to the compiled variant.
        let sample_len: usize = self.input_shape.iter().product();
        let mut data = qx.data().to_vec();
        data.resize(batch * sample_len, 0);
        let mut shape = vec![*batch];
        shape.extend_from_slice(&self.input_shape);
        let mut args = self.base_args.clone();
        args.push(Tensor::from_vec(&shape, data).into());
        let outs = exe.run(&args)?;
        // First output is the logits batch; strip the padding rows.
        let logits = match outs.into_iter().next().context("executable produced no outputs")? {
            Arg::I32(t) => Arg::I32(t.slice_batch(0, n)),
            Arg::F32(t) => Arg::F32(t.slice_batch(0, n)),
        };
        Ok(ExecOutput { logits })
    }
}
