//! [`NetServer`]: the socket front-end over a serving
//! [`ServerHandle`].
//!
//! The server is a framing + dispatch shim: it owns no models and no
//! batching. Every request op is answered by calling the corresponding
//! `ServerHandle` method, so the coordinator's invariants (swap atomic
//! w.r.t. in-flight batches, metrics ledgers spanning versions,
//! deadline semantics) hold for remote callers exactly as for
//! in-process ones.
//!
//! Threading: an accept thread polls a non-blocking listener and hands
//! accepted connections to a fixed pool of handler threads; each
//! handler serves one connection at a time, frame by frame (requests on
//! one connection are processed in order, which is what makes client
//! pipelining deterministic). Connections beyond the pool size queue
//! until a handler frees up.
//!
//! Failure discipline: every detectable failure gets a typed
//! `ReplyErr` frame before anything else happens — a client never sees
//! a silently dropped connection. Fatal errors (malformed header,
//! truncated frame, version mismatch, oversized frame) close the
//! connection *after* the reply because the byte stream is
//! desynchronized; payload-level errors (checksum mismatch, unknown
//! model, deadline exceeded, bad request) leave the connection usable.
//!
//! Shutdown: [`NetServer::stop`] flips a flag checked only *between*
//! frames, so a request already being served completes and its reply is
//! written (graceful drain), then handlers close their connections and
//! join. Idle connections are reaped after `idle_timeout` without a
//! frame.

use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::coordinator::ServerHandle;
use crate::io::fnv1a64;

use super::protocol::{
    encode_error, encode_model_infos, pack_lossless, Frame, Header, Opcode,
    PayloadReader, PayloadWriter, WireCode, WireError, WireMetrics, WireModelInfo,
    HEADER_LEN, MAX_PAYLOAD, TRAILER_LEN,
};

/// Socket-layer configuration (the serving layer's knobs live in
/// [`crate::coordinator::ServerConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Handler threads = max concurrently served connections.
    pub handler_threads: usize,
    /// Mid-frame stall limit: a peer that starts a frame and then sends
    /// nothing for this long gets a typed truncated-frame reply and a
    /// close (it cannot hold a handler hostage).
    pub read_timeout: Duration,
    /// Socket write timeout for replies.
    pub write_timeout: Duration,
    /// A connection with no frame for this long is reaped.
    pub idle_timeout: Duration,
    /// Per-frame payload cap; a larger declared length is a typed
    /// `FrameTooLarge` error and the payload is never read.
    pub max_payload: u32,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            handler_threads: 8,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(60),
            max_payload: MAX_PAYLOAD,
        }
    }
}

/// Poll granularity for the accept loop and for blocked reads — bounds
/// how long shutdown/idle checks can lag.
const POLL_TICK: Duration = Duration::from_millis(20);

/// The socket front-end. Bind, serve, [`stop`](NetServer::stop).
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an OS-assigned port) and
    /// start serving `handle` immediately.
    pub fn bind(
        addr: impl ToSocketAddrs,
        handle: ServerHandle,
        cfg: NetConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).context("binding listener")?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;

        let stop = Arc::new(AtomicBool::new(false));
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let mut threads = Vec::with_capacity(cfg.handler_threads + 1);
        {
            let stop = stop.clone();
            threads.push(std::thread::spawn(move || {
                accept_loop(listener, conn_tx, stop);
            }));
        }
        for _ in 0..cfg.handler_threads.max(1) {
            let conn_rx = conn_rx.clone();
            let handle = handle.clone();
            let stop = stop.clone();
            threads.push(std::thread::spawn(move || loop {
                let stream = {
                    let guard = conn_rx.lock().unwrap();
                    guard.recv_timeout(Duration::from_millis(50))
                };
                match stream {
                    Ok(s) => serve_connection(s, &handle, &cfg, &stop),
                    Err(RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            }));
        }
        Ok(NetServer { local_addr, stop, threads })
    }

    /// The bound address (the real port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, drain: requests already being served complete
    /// and their replies are written before the threads join. The
    /// serving coordinator behind the handle is untouched — stop it
    /// separately via `Server::stop()`.
    pub fn stop(self) {
        self.stop.store(true, Ordering::SeqCst);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    conn_tx: mpsc::Sender<TcpStream>,
    stop: Arc<AtomicBool>,
) {
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The accepted socket may inherit the listener's
                // non-blocking mode on some platforms; the handlers'
                // poll-tick reads want a blocking socket with a short
                // read timeout instead.
                let ok = stream.set_nonblocking(false).is_ok()
                    && stream.set_read_timeout(Some(POLL_TICK)).is_ok();
                let _ = stream.set_nodelay(true);
                if ok && conn_tx.send(stream).is_err() {
                    return; // handlers gone: shutting down
                }
            }
            Err(e) if is_would_block(&e) => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                // Transient accept failure (e.g. aborted handshake).
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn is_would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Read exactly `buf.len()` bytes, tolerating short reads and poll-tick
/// timeouts, failing if the peer closes mid-frame or stalls longer than
/// `stall_limit` since the last byte.
fn fill(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stall_limit: Duration,
) -> std::result::Result<(), String> {
    let mut pos = 0;
    let mut last_byte = Instant::now();
    while pos < buf.len() {
        match stream.read(&mut buf[pos..]) {
            Ok(0) => {
                return Err(format!(
                    "peer closed the connection mid-frame ({pos} of {} bytes)",
                    buf.len()
                ))
            }
            Ok(n) => {
                pos += n;
                last_byte = Instant::now();
            }
            Err(e) if is_would_block(&e) || e.kind() == ErrorKind::Interrupted => {
                if last_byte.elapsed() >= stall_limit {
                    return Err(format!(
                        "frame stalled mid-transfer for {stall_limit:?} \
                         ({pos} of {} bytes)",
                        buf.len()
                    ));
                }
            }
            Err(e) => return Err(format!("socket read failed: {e}")),
        }
    }
    Ok(())
}

/// Best-effort typed error reply; the connection may already be dead,
/// in which case there is nobody left to inform.
fn reply_err(stream: &mut TcpStream, req_id: u64, e: &WireError) {
    let frame = Frame::new(Opcode::ReplyErr, req_id, encode_error(e));
    let _ = frame.write_to(stream);
}

/// Serve one connection frame-by-frame until close / fatal error /
/// idle reap / shutdown. The shutdown flag is checked only between
/// frames: a request already past its header completes and replies.
fn serve_connection(
    mut stream: TcpStream,
    handle: &ServerHandle,
    cfg: &NetConfig,
    stop: &AtomicBool,
) {
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let mut last_frame = Instant::now();
    loop {
        // Frame boundary: wait for the first header byte, watching the
        // shutdown flag and the idle clock.
        let mut hdr = [0u8; HEADER_LEN];
        let mut got = 0usize;
        loop {
            if stop.load(Ordering::SeqCst) {
                return; // between frames: nothing in flight on this conn
            }
            match stream.read(&mut hdr) {
                Ok(0) => return, // clean close at a frame boundary
                Ok(n) => {
                    got = n;
                    break;
                }
                Err(e) if is_would_block(&e) || e.kind() == ErrorKind::Interrupted => {
                    if last_frame.elapsed() >= cfg.idle_timeout {
                        return; // idle reap
                    }
                }
                Err(_) => return,
            }
        }
        if got < HEADER_LEN {
            if let Err(msg) = fill(&mut stream, &mut hdr[got..], cfg.read_timeout) {
                reply_err(
                    &mut stream,
                    0,
                    &WireError::new(
                        WireCode::MalformedFrame,
                        format!("truncated frame header: {msg}"),
                    ),
                );
                return;
            }
        }
        // req_id sits at a fixed offset; echo it even on malformed
        // frames so a pipelining client can attribute the failure. (If
        // the magic itself is wrong these bytes are noise — harmless.)
        let req_id = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        let header = match Header::parse(&hdr, cfg.max_payload) {
            Ok(h) => h,
            Err(e) => {
                reply_err(&mut stream, req_id, &e);
                if e.fatal() {
                    return;
                }
                continue;
            }
        };
        let mut payload = vec![0u8; header.payload_len as usize];
        if let Err(msg) = fill(&mut stream, &mut payload, cfg.read_timeout) {
            reply_err(
                &mut stream,
                req_id,
                &WireError::new(
                    WireCode::MalformedFrame,
                    format!("truncated frame payload: {msg}"),
                ),
            );
            return;
        }
        let mut trailer = [0u8; TRAILER_LEN];
        if let Err(msg) = fill(&mut stream, &mut trailer, cfg.read_timeout) {
            reply_err(
                &mut stream,
                req_id,
                &WireError::new(
                    WireCode::MalformedFrame,
                    format!("truncated checksum trailer: {msg}"),
                ),
            );
            return;
        }
        last_frame = Instant::now();

        let want = u64::from_le_bytes(trailer);
        let got_sum = fnv1a64(&payload);
        if want != got_sum {
            // Framing was intact, only the payload is corrupt — the
            // stream stays in sync, so the connection survives.
            reply_err(
                &mut stream,
                req_id,
                &WireError::new(
                    WireCode::ChecksumMismatch,
                    format!(
                        "payload checksum {got_sum:#018x} != trailer {want:#018x}"
                    ),
                ),
            );
            continue;
        }
        let op = match Opcode::from_u8(header.opcode_raw) {
            Some(op @ (Opcode::ReplyOk | Opcode::ReplyErr)) => {
                reply_err(
                    &mut stream,
                    req_id,
                    &WireError::new(
                        WireCode::BadRequest,
                        format!("{op:?} is a reply opcode, not a request"),
                    ),
                );
                continue;
            }
            Some(op) => op,
            None => {
                reply_err(
                    &mut stream,
                    req_id,
                    &WireError::new(
                        WireCode::BadRequest,
                        format!("unknown opcode {:#04x}", header.opcode_raw),
                    ),
                );
                continue;
            }
        };
        match dispatch(handle, op, &payload) {
            Ok(reply) => {
                if Frame::new(Opcode::ReplyOk, req_id, reply)
                    .write_to(&mut stream)
                    .is_err()
                {
                    return; // peer gone mid-reply
                }
            }
            Err(e) => {
                reply_err(&mut stream, req_id, &e);
                if e.fatal() {
                    return;
                }
            }
        }
    }
}

/// Execute one request op against the serving handle and produce the
/// `ReplyOk` payload. All serving-side failures map to typed wire
/// errors via [`WireError::from_serving`].
fn dispatch(
    handle: &ServerHandle,
    op: Opcode,
    payload: &[u8],
) -> std::result::Result<Vec<u8>, WireError> {
    let mut r = PayloadReader::new(payload);
    match op {
        Opcode::Ping => {
            r.expect_end()?;
            Ok(Vec::new())
        }
        Opcode::Infer => {
            let model = r.get_str()?;
            let qx = r.get_qtensor()?;
            r.expect_end()?;
            let logits = handle
                .infer(&model, qx.widen())
                .map_err(|e| WireError::from_serving(&e))?;
            let mut w = PayloadWriter::new();
            w.put_qtensor(&pack_lossless(&logits));
            Ok(w.finish())
        }
        Opcode::InferDeadline => {
            let model = r.get_str()?;
            let deadline_us = r.get_u64()?;
            let qx = r.get_qtensor()?;
            r.expect_end()?;
            let logits = handle
                .infer_deadline(
                    &model,
                    qx.widen(),
                    Duration::from_micros(deadline_us),
                )
                .map_err(|e| WireError::from_serving(&e))?;
            let mut w = PayloadWriter::new();
            w.put_qtensor(&pack_lossless(&logits));
            Ok(w.finish())
        }
        Opcode::LoadModel => {
            let name = r.get_str()?;
            let path = r.get_str()?;
            r.expect_end()?;
            handle
                .load_model_from_artifact(&name, &path)
                .map_err(|e| WireError::from_serving(&e))?;
            let mut w = PayloadWriter::new();
            w.put_u64(1); // a fresh registration always starts at v1
            Ok(w.finish())
        }
        Opcode::SwapModel => {
            let name = r.get_str()?;
            let path = r.get_str()?;
            r.expect_end()?;
            let version = handle
                .swap_model_from_artifact(&name, &path)
                .map_err(|e| WireError::from_serving(&e))?;
            let mut w = PayloadWriter::new();
            w.put_u64(version);
            Ok(w.finish())
        }
        Opcode::UnloadModel => {
            let name = r.get_str()?;
            r.expect_end()?;
            handle
                .unload_model(&name)
                .map_err(|e| WireError::from_serving(&e))?;
            Ok(Vec::new())
        }
        Opcode::ListModels => {
            r.expect_end()?;
            // The registry returns the table sorted by name — the wire
            // op inherits (and its tests lock in) that determinism.
            let infos: Vec<WireModelInfo> = handle
                .list_models()
                .into_iter()
                .map(|m| WireModelInfo {
                    name: m.name,
                    version: m.version,
                    backend: m.backend,
                    input_shape: m.input_shape,
                    max_batch: m.max_batch as u32,
                    provenance: m.provenance.to_string(),
                })
                .collect();
            Ok(encode_model_infos(&infos))
        }
        Opcode::ModelMetrics => {
            let name = r.get_str()?;
            r.expect_end()?;
            let mut m = handle
                .model_metrics(&name)
                .map_err(|e| WireError::from_serving(&e))?;
            Ok(WireMetrics::from_metrics(&mut m).encode())
        }
        Opcode::ReplyOk | Opcode::ReplyErr => Err(WireError::new(
            WireCode::BadRequest,
            "reply opcodes are not requests",
        )),
    }
}
