//! [`NemoClient`]: blocking client for the NEMO wire protocol.
//!
//! One client owns one TCP connection and speaks request/reply frames
//! over it. Calls take `&mut self` — the protocol multiplexes by
//! `req_id`, but a single blocking connection is serial by nature.
//! Pipelining is explicit ([`NemoClient::infer_pipelined`]): write all
//! request frames first, then drain all replies, which amortizes the
//! round-trip latency without concurrency.
//!
//! Failure surface: protocol-level failures are typed
//! [`WireError`]s inside `anyhow::Error` — recover the code with
//! `err.downcast_ref::<WireError>()`. The deadline of
//! [`infer_deadline`](NemoClient::infer_deadline) is enforced
//! *server-side* (it propagates to the coordinator's reply deadline);
//! the client stretches its socket timeout so the typed
//! `DeadlineExceeded` reply, not a local socket timeout, is what the
//! caller sees.

use std::io::Write as _;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, bail, Context as _, Result};

use crate::tensor::{QTensor, TensorI};

use super::protocol::{
    decode_error, decode_model_infos, pack_lossless, read_frame, Frame, Opcode,
    PayloadReader, PayloadWriter, WireMetrics, WireModelInfo, MAX_PAYLOAD,
};

/// Connection/retry/timeout knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClientConfig {
    /// Extra connect attempts after the first (handy when racing a
    /// server that is still binding its listener).
    pub connect_retries: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub retry_backoff: Duration,
    /// Socket read timeout for a single reply.
    pub read_timeout: Duration,
    pub write_timeout: Duration,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_retries: 5,
            retry_backoff: Duration::from_millis(20),
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
        }
    }
}

/// Blocking wire-protocol client; see the module docs.
pub struct NemoClient {
    stream: TcpStream,
    cfg: ClientConfig,
    next_req_id: u64,
}

impl NemoClient {
    /// Connect with the default config.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NemoClient> {
        Self::connect_with(addr, ClientConfig::default())
    }

    /// Connect with retry/backoff per `cfg`.
    pub fn connect_with(addr: impl ToSocketAddrs, cfg: ClientConfig) -> Result<NemoClient> {
        let mut backoff = cfg.retry_backoff;
        let mut last_err: Option<std::io::Error> = None;
        for attempt in 0..=cfg.connect_retries {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff *= 2;
            }
            // Re-resolve per attempt; try every resolved address.
            let addrs = addr
                .to_socket_addrs()
                .context("resolving server address")?;
            for a in addrs {
                match TcpStream::connect(a) {
                    Ok(stream) => {
                        stream
                            .set_read_timeout(Some(cfg.read_timeout))
                            .context("setting read timeout")?;
                        stream
                            .set_write_timeout(Some(cfg.write_timeout))
                            .context("setting write timeout")?;
                        let _ = stream.set_nodelay(true);
                        return Ok(NemoClient { stream, cfg, next_req_id: 1 });
                    }
                    Err(e) => last_err = Some(e),
                }
            }
        }
        Err(match last_err {
            Some(e) => anyhow!(e).context(format!(
                "connecting failed after {} attempts",
                cfg.connect_retries + 1
            )),
            None => anyhow!("server address resolved to no candidates"),
        })
    }

    fn fresh_req_id(&mut self) -> u64 {
        let id = self.next_req_id;
        self.next_req_id += 1;
        id
    }

    /// Write one request frame.
    fn send(&mut self, opcode: Opcode, payload: Vec<u8>) -> Result<u64> {
        let req_id = self.fresh_req_id();
        Frame::new(opcode, req_id, payload)
            .write_to(&mut self.stream)
            .context("writing request frame")?;
        Ok(req_id)
    }

    /// Read the reply for `req_id` and unwrap it to the `ReplyOk`
    /// payload; a `ReplyErr` becomes a typed [`super::WireError`].
    fn recv(&mut self, req_id: u64) -> Result<Vec<u8>> {
        let frame = read_frame(&mut self.stream, MAX_PAYLOAD)
            .map_err(|e| anyhow!(e).context("reading reply frame"))?;
        if frame.req_id != req_id {
            bail!(
                "reply req_id {} does not match request {} \
                 (connection out of sync)",
                frame.req_id,
                req_id
            );
        }
        match frame.opcode {
            Opcode::ReplyOk => Ok(frame.payload),
            Opcode::ReplyErr => Err(decode_error(&frame.payload).into()),
            other => bail!("server sent non-reply opcode {other:?}"),
        }
    }

    /// One full request/reply round-trip.
    fn call(&mut self, opcode: Opcode, payload: Vec<u8>) -> Result<Vec<u8>> {
        let req_id = self.send(opcode, payload)?;
        self.recv(req_id)
    }

    // -- ops ---------------------------------------------------------

    /// Liveness heartbeat: a full round-trip through the server's frame
    /// loop with an empty payload.
    pub fn ping(&mut self) -> Result<()> {
        let reply = self.call(Opcode::Ping, Vec::new())?;
        if !reply.is_empty() {
            bail!("ping reply carried {} unexpected bytes", reply.len());
        }
        Ok(())
    }

    /// Remote single-sample inference. The integer image crosses the
    /// wire at packed precision (lossless); the reply widens back to
    /// the i32 logits image, bit-identical to in-process
    /// `ServerHandle::infer`.
    pub fn infer(&mut self, model: &str, qx: &TensorI) -> Result<TensorI> {
        let payload = Self::infer_payload(model, qx);
        let reply = self.call(Opcode::Infer, payload)?;
        Self::decode_logits(&reply)
    }

    /// Remote inference with a server-side reply deadline. The socket
    /// timeout is stretched past the deadline so the typed
    /// `DeadlineExceeded` reply makes it back instead of a local
    /// socket timeout racing it.
    pub fn infer_deadline(
        &mut self,
        model: &str,
        qx: &TensorI,
        deadline: Duration,
    ) -> Result<TensorI> {
        let mut w = PayloadWriter::new();
        w.put_str(model);
        w.put_u64(deadline.as_micros().min(u64::MAX as u128) as u64);
        w.put_qtensor(&pack_lossless(qx));
        let stretched = deadline + self.cfg.read_timeout;
        self.stream
            .set_read_timeout(Some(stretched))
            .context("stretching read timeout for deadline call")?;
        let result = self.call(Opcode::InferDeadline, w.finish());
        let _ = self.stream.set_read_timeout(Some(self.cfg.read_timeout));
        Self::decode_logits(&result?)
    }

    /// Pipelined inference: write every request frame back-to-back,
    /// then drain the replies in order. One connection, no concurrency
    /// — the round-trip latency is paid once instead of `n` times. On
    /// a per-request error the remaining replies are still drained (the
    /// connection stays in sync) and the first error is returned.
    pub fn infer_pipelined(
        &mut self,
        model: &str,
        inputs: &[TensorI],
    ) -> Result<Vec<TensorI>> {
        let mut ids = Vec::with_capacity(inputs.len());
        for qx in inputs {
            ids.push(self.send(Opcode::Infer, Self::infer_payload(model, qx))?);
        }
        self.stream.flush().context("flushing pipelined requests")?;
        let mut out = Vec::with_capacity(inputs.len());
        let mut first_err: Option<anyhow::Error> = None;
        for id in ids {
            match self.recv(id).and_then(|p| Self::decode_logits(&p)) {
                Ok(t) => out.push(t),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    /// Register a new model from a *server-side* artifact path.
    pub fn load_model(&mut self, name: &str, path: &str) -> Result<u64> {
        self.version_op(Opcode::LoadModel, name, path)
    }

    /// Hot-swap `name` to a server-side artifact; returns the new
    /// version. Atomic w.r.t. in-flight remote requests (the
    /// coordinator's contract).
    pub fn swap_model(&mut self, name: &str, path: &str) -> Result<u64> {
        self.version_op(Opcode::SwapModel, name, path)
    }

    fn version_op(&mut self, op: Opcode, name: &str, path: &str) -> Result<u64> {
        let mut w = PayloadWriter::new();
        w.put_str(name);
        w.put_str(path);
        let reply = self.call(op, w.finish())?;
        let mut r = PayloadReader::new(&reply);
        let version = r.get_u64().map_err(anyhow::Error::from)?;
        r.expect_end().map_err(anyhow::Error::from)?;
        Ok(version)
    }

    /// Remove `name` from serving.
    pub fn unload_model(&mut self, name: &str) -> Result<()> {
        let mut w = PayloadWriter::new();
        w.put_str(name);
        let reply = self.call(Opcode::UnloadModel, w.finish())?;
        if !reply.is_empty() {
            bail!("unload reply carried {} unexpected bytes", reply.len());
        }
        Ok(())
    }

    /// Every served model, sorted by name (wire-guaranteed).
    pub fn list_models(&mut self) -> Result<Vec<WireModelInfo>> {
        let reply = self.call(Opcode::ListModels, Vec::new())?;
        decode_model_infos(&reply).map_err(anyhow::Error::from)
    }

    /// One model's metrics ledger (spans swap versions).
    pub fn model_metrics(&mut self, name: &str) -> Result<WireMetrics> {
        let mut w = PayloadWriter::new();
        w.put_str(name);
        let reply = self.call(Opcode::ModelMetrics, w.finish())?;
        WireMetrics::decode(&reply).map_err(anyhow::Error::from)
    }

    fn infer_payload(model: &str, qx: &TensorI) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.put_str(model);
        w.put_qtensor(&pack_lossless(qx));
        w.finish()
    }

    fn decode_logits(payload: &[u8]) -> Result<TensorI> {
        let mut r = PayloadReader::new(payload);
        let qt: QTensor = r.get_qtensor().map_err(anyhow::Error::from)?;
        r.expect_end().map_err(anyhow::Error::from)?;
        Ok(qt.widen())
    }
}
