//! Mini property-testing harness (proptest is not in the offline vendor
//! set). Runs a closure over N seeded random cases; on failure it reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```ignore
//! prop_check(200, |rng| {
//!     let n = rng.int(1, 100) as usize;
//!     ... generate inputs, return Err(msg) on violated invariant ...
//! });
//! ```

use super::rng::Rng;

/// Run `cases` random trials. `f` gets a per-case RNG and returns
/// Err(description) when the property is violated. Panics with the seed
/// on first failure.
pub fn prop_check<F>(cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        // Derive the case seed so any failure is replayable in isolation.
        let seed = 0x5EED_0000u64 ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property violated (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn prop_replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property violated (seed {seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check(50, |rng| {
            let x = rng.int(0, 100);
            if (0..100).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property violated")]
    fn fails_loudly() {
        prop_check(50, |rng| {
            let x = rng.int(0, 100);
            Err(format!("always fails (x={x})"))
        });
    }
}
