"""Threshold-activation Pallas kernel (Eq. 19-20, BN+act merge — exact).

    Q_y(varphi) = sum_i i * chi_[TH_i, TH_{i+1})(Q(varphi))

realized as a popcount of satisfied thresholds: out = #{i : q >= TH_i},
with per-channel ascending thresholds TH (shape [C, N]). This is the
paper's "especially effective when the cardinality of Z_y is small" path:
a 2-bit output needs N = 3 comparisons, no multiplier at all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INT, INTERPRET, cdiv, pad_to


def _thresh_kernel(q_ref, th_ref, o_ref):
    q = q_ref[...]                      # [br, bc]
    th = th_ref[...]                    # [bc, N]
    cmp = q[:, :, None] >= th[None, :, :]
    o_ref[...] = jnp.sum(cmp.astype(INT), axis=-1)


def thresh(q: jnp.ndarray, thresholds: jnp.ndarray, *, br: int = 256,
           bc: int = 32) -> jnp.ndarray:
    """q: [R, C] int32; thresholds: [C, N] int32 ascending per channel."""
    r, c = q.shape
    c2, n = thresholds.shape
    assert c == c2
    qp = pad_to(pad_to(q, 0, br), 1, bc)
    # Pad channels with +inf-like thresholds so padded columns emit 0.
    thp = pad_to(thresholds, 0, bc, value=2**31 - 1)
    out = pl.pallas_call(
        _thresh_kernel,
        grid=(cdiv(r, br), cdiv(c, bc)),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bc, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, INT),
        interpret=INTERPRET,
    )(qp, thp)
    return out[:r, :c]
