"""Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, values, and tile sizes; every comparison is
exact (integer) equality — these are integer kernels, allclose would hide
real bugs.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.avgpool import avgpool
from compile.kernels.intbn import intbn
from compile.kernels.qgemm import qgemm, qgemm_bn_requant
from compile.kernels.requant import requant
from compile.kernels.thresh import thresh

SETTINGS = dict(max_examples=20, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


@given(m=st.integers(1, 90), k=st.integers(1, 90), n=st.integers(1, 40),
       bm=st.sampled_from([8, 32, 64]), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_qgemm_matches_ref(m, k, n, bm, seed):
    r = _rng(seed)
    a = jnp.asarray(r.integers(-255, 256, (m, k)), jnp.int32)
    b = jnp.asarray(r.integers(-128, 128, (k, n)), jnp.int32)
    got = qgemm(a, b, bm=bm, bk=bm, bn=bm)
    assert np.array_equal(got, ref.qgemm_ref(a, b))


@given(m=st.integers(1, 60), k=st.integers(1, 60), n=st.integers(1, 30),
       d=st.integers(4, 24), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_qgemm_fused_matches_ref(m, k, n, d, seed):
    r = _rng(seed)
    a = jnp.asarray(r.integers(0, 256, (m, k)), jnp.int32)
    b = jnp.asarray(r.integers(-128, 128, (k, n)), jnp.int32)
    kq = jnp.asarray(r.integers(-127, 128, (n,)), jnp.int32)
    lq = jnp.asarray(r.integers(-2**20, 2**20, (n,)), jnp.int32)
    mm = int(r.integers(16, 64))
    got = qgemm_bn_requant(a, b, kq, lq, jnp.int32(mm), jnp.int32(d),
                           jnp.int32(0), jnp.int32(255), bm=32, bk=32, bn=32)
    want = ref.intbn_requant_ref(ref.qgemm_ref(a, b), kq, lq, mm, d, 0, 255)
    assert np.array_equal(got, want)


@given(n=st.integers(1, 10000), m=st.integers(1, 64), d=st.integers(0, 30),
       neg=st.booleans(), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_requant_matches_ref(n, m, d, neg, seed):
    r = _rng(seed)
    lo = -2**27 if neg else 0
    q = jnp.asarray(r.integers(lo, 2**27, (n,)), jnp.int32)
    got = requant(q, jnp.int32(m), jnp.int32(d), jnp.int32(0), jnp.int32(255))
    assert np.array_equal(got, ref.requant_ref(q, m, d, 0, 255))


def test_requant_negative_floor_semantics():
    # (m*q) >> d must floor toward -inf, not truncate toward zero.
    q = jnp.asarray([-1, -3, -255, -256, -257], jnp.int32)
    got = requant(q, jnp.int32(1), jnp.int32(8), jnp.int32(-100),
                  jnp.int32(100))
    assert got.tolist() == [-1, -1, -1, -1, -2]


@given(rows=st.integers(1, 300), c=st.integers(1, 70),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_intbn_matches_ref(rows, c, seed):
    r = _rng(seed)
    q = jnp.asarray(r.integers(-2**22, 2**22, (rows, c)), jnp.int32)
    kq = jnp.asarray(r.integers(-127, 128, (c,)), jnp.int32)
    lq = jnp.asarray(r.integers(-2**26, 2**26, (c,)), jnp.int32)
    got = intbn(q, kq, lq, br=64, bc=16)
    assert np.array_equal(got, ref.intbn_ref(q, kq, lq))


@given(rows=st.integers(1, 200), c=st.integers(1, 40),
       nlev=st.sampled_from([3, 15, 255]), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_thresh_matches_ref(rows, c, nlev, seed):
    r = _rng(seed)
    th = np.sort(r.integers(-1000, 1000, (c, nlev)), axis=1).astype(np.int32)
    q = jnp.asarray(r.integers(-1500, 1500, (rows, c)), jnp.int32)
    got = thresh(q, jnp.asarray(th), br=64, bc=8)
    assert np.array_equal(got, ref.thresh_ref(q, jnp.asarray(th)))


@given(b=st.integers(1, 4), c=st.integers(1, 40),
       k=st.sampled_from([2, 4]), tiles=st.integers(1, 3),
       d=st.integers(8, 20), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_avgpool_matches_ref(b, c, k, tiles, d, seed):
    r = _rng(seed)
    hw = k * tiles
    q = jnp.asarray(r.integers(0, 256, (b, c, hw, hw)), jnp.int32)
    got = avgpool(q, k, k, d, bc=8)
    assert np.array_equal(got, ref.avgpool_ref(q, k, k, d))


def test_im2col_matches_conv():
    # im2col + gemm must equal lax.conv on the same integer data.
    import jax

    r = _rng(0)
    x = jnp.asarray(r.integers(0, 256, (2, 3, 8, 8)), jnp.int32)
    w = jnp.asarray(r.integers(-128, 128, (5, 3, 3, 3)), jnp.int32)
    cols, (b, oh, ow) = ref.im2col_ref(x, 3, 3, 2, 1)
    wmat = w.transpose(1, 2, 3, 0).reshape(27, 5)
    got = ref.qgemm_ref(cols, wmat).reshape(b, oh, ow, 5).transpose(0, 3, 1, 2)
    want = jax.lax.conv_general_dilated(
        x, w, (2, 2), ((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    assert np.array_equal(got, np.asarray(want, np.int32))
