"""Pure-jnp oracles for the Pallas kernels (correctness reference).

Every kernel in this package is validated against these functions by
python/tests/test_kernels.py (hypothesis sweeps over shapes/values) before
anything is AOT-exported. These are the "ground truth" implementations of
the paper's integer-domain equations; they are deliberately written as
straight transcriptions with no tiling or fusion.
"""

from __future__ import annotations

import jax.numpy as jnp

INT = jnp.int32
WIDE = jnp.int64


def qgemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Integer-image GEMM, Eq. 16: Q(varphi) = sum_n Q_w * Q_x. [M,K]x[K,N]."""
    return jnp.matmul(a.astype(WIDE), b.astype(WIDE)).astype(INT)


def requant_ref(q, m: int, d: int, lo: int, hi: int):
    """clip((m * q) >> d, lo, hi) with floor semantics (Eq. 11/13)."""
    wide = q.astype(WIDE) * WIDE(m)
    shifted = jnp.right_shift(wide, WIDE(d))
    return jnp.clip(shifted, lo, hi).astype(INT)


def intbn_ref(q, kappa_q, lambda_q):
    """Q(kappa)*Q(varphi) + Q(lambda) per channel (Eq. 22). q: [R, C]."""
    out = q.astype(WIDE) * kappa_q.astype(WIDE)[None, :] + lambda_q.astype(WIDE)[None, :]
    return out.astype(INT)


def intbn_requant_ref(q, kappa_q, lambda_q, m: int, d: int, lo: int, hi: int):
    """Fused integer BN + requantization + clip (the ID layer epilogue)."""
    bn = q.astype(WIDE) * kappa_q.astype(WIDE)[None, :] + lambda_q.astype(WIDE)[None, :]
    wide = bn * WIDE(m)
    shifted = jnp.right_shift(wide, WIDE(d))
    return jnp.clip(shifted, lo, hi).astype(INT)


def thresh_ref(q, thresholds):
    """Threshold activation (Eq. 20). q: [R, C]; thresholds: [C, N] ascending.

    Output integer = #{i : q >= TH_i}, i.e. the staircase sum_i i*chi over
    consecutive threshold intervals, clipped to [0, N] by construction.
    """
    cmp = q[:, :, None] >= thresholds[None, :, :]
    return jnp.sum(cmp.astype(INT), axis=-1)


def avgpool_ref(q, k1: int, k2: int, d: int):
    """Integer average pool (Eq. 25), window (k1,k2), stride = window.

    q: [B, C, H, W] int32; H % k1 == 0, W % k2 == 0.
    """
    b, c, h, w = q.shape
    r = q.reshape(b, c, h // k1, k1, w // k2, k2)
    acc = jnp.sum(r.astype(WIDE), axis=(3, 5))
    m = (1 << d) // (k1 * k2)
    return jnp.right_shift(acc * WIDE(m), WIDE(d)).astype(INT)


def im2col_ref(x, kh: int, kw: int, stride: int, pad: int):
    """im2col for NCHW integer tensors.

    Returns patches [B*OH*OW, C*kh*kw] so conv = qgemm(patches, w_mat) with
    w_mat [C*kh*kw, C_out].
    """
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[:, :, i:i + stride * oh:stride, j:j + stride * ow:stride]
            cols.append(patch)
    stacked = jnp.stack(cols, axis=-1)  # [B, C, OH, OW, kh*kw]
    out = stacked.transpose(0, 2, 3, 1, 4).reshape(b * oh * ow, c * kh * kw)
    return out, (b, oh, ow)
