//! Native deployment artifacts: save/load a complete IntegerDeployable
//! model as a single self-contained file — the v2 JSON form
//! (`model.nemo.json`) or the v3 binary container (`model.nemob`).
//!
//! The paper's IntegerDeployable representation is a frozen integer
//! program — topology, packed weights, requantization parameters
//! `(m, d, lo, hi)`, integer BN / thresholds, per-node storage precision
//! stamps and the eps bookkeeping needed to interpret the output. This
//! module makes that program the primary shipping unit: `nemo deploy
//! --save m.nemo.json` writes it once, `nemo serve --model m.nemo.json`
//! serves it anywhere with zero training or transform work, and the
//! loader guarantees bit-identity with the in-memory network that
//! produced the file (DESIGN.md §Artifact-format).
//!
//! Integrity contract, enforced on load:
//!
//! * `format` / `version` fields gate the schema — wrong ones are typed
//!   errors, never a best-effort parse;
//! * a FNV-1a 64 checksum over the canonical JSON of the `model` subtree
//!   detects corruption and hand edits;
//! * weight payloads are stored at their packed precision (`u8`/`i8`
//!   payloads for sub-word grids, hex-encoded bit-packed payloads for
//!   the sub-byte `u1`/`u2`/`u4`/`i4` grids at 2–8 weights per byte,
//!   `i32` for wide) and re-narrowed through [`QTensor::narrow_from`]
//!   (or [`PackedTensor::from_bytes`]) on load, so an out-of-range or
//!   malformed payload fails loudly;
//! * every node's stamped [`Precision`] is re-proved by
//!   [`infer_precision`] after reconstruction — a tampered stamp cannot
//!   reach the packed kernels.
//!
//! The v3 binary container keeps that whole contract and adds a
//! zero-copy cold-load path (DESIGN.md §Artifact-format v3):
//!
//! ```text
//! [ 8B magic "NEMOBIN\0" | u32 LE container version | u32 LE header len ]
//! [ JSON header: {checksum, format, model, sections, version} ]
//! [ zero pad to the 64-byte payload base ]
//! [ section 0 bytes | pad to 64 | section 1 bytes | ... ]
//! ```
//!
//! The header's `model` subtree is the v2 schema with every weight
//! payload replaced by a `{dtype, shape, section}` reference into the
//! section table; each section records its payload length and an
//! FNV-1a 64 checksum over the raw bytes. Payloads are byte-identical
//! to the in-memory packed representation (`u8`/`i8` bytes, `i32`
//! little-endian, sub-byte bitstreams), and every section offset is
//! 64-byte aligned, so the loader `mmap`s the file and hands the graph
//! [`QTensor`] *views* borrowing the mapping — weight bytes are never
//! copied on the map path ([`BinLoadStats`] proves it).

use std::path::Path;
use std::sync::Arc;

use crate::analysis::{CheckMode, Severity};
use crate::graph::int::{IntGraph, IntOp};
use crate::graph::shape::{infer_precision, ShapeError};
use crate::graph::Graph;
use crate::network::StageMeta;
use crate::quant::bn::{BnQuant, Thresholds};
use crate::quant::requant::Requant;
use crate::quant::{Precision, QuantSpec};
use crate::io::mmap::{AlignedBytes, BinLoadMode, MappedFile};
use crate::tensor::{ByteSource, PackedTensor, QTensor, Tensor, TensorI};
use crate::transform::{Deployed, LayerQuant};
use crate::util::json::{self, JsonError, Value};

/// Magic format tag of a native deployment artifact.
pub const FORMAT: &str = "nemo-deployed-model";
/// Schema version this build writes. v2 added bit-packed sub-byte
/// weight payloads (`u1`/`u2`/`u4`/`i4` dtypes with a hex `packed`
/// field instead of the `data` int array).
pub const VERSION: i64 = 2;
/// Oldest schema version this build still reads. v1 documents decode
/// unchanged; sub-byte dtypes inside one are rejected with a typed
/// [`ArtifactError::DtypeVersion`] — a v1 writer cannot have produced
/// them, so the file is forged or spliced, not merely old.
pub const MIN_VERSION: i64 = 1;
/// First schema version whose readers understand sub-byte dtypes.
const SUBBYTE_VERSION: i64 = 2;

/// Leading magic of the v3 binary container (`model.nemob`).
pub const BIN_MAGIC: [u8; 8] = *b"NEMOBIN\0";
/// Container version the binary writer emits (and the only one this
/// build reads). The embedded JSON header declares the same number.
pub const BIN_VERSION: u32 = 3;
/// Every weight section starts on this boundary, so an `mmap` of the
/// file (page-aligned) or the 8-aligned read fallback can back typed
/// tensor views for every dtype a section can hold.
pub const BIN_ALIGN: usize = 64;

fn align_up(n: usize) -> usize {
    n.div_ceil(BIN_ALIGN) * BIN_ALIGN
}

#[derive(Debug, thiserror::Error)]
pub enum ArtifactError {
    #[error("artifact I/O at {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
    #[error("artifact JSON: {0}")]
    Json(#[from] JsonError),
    #[error("not a NEMO deployment artifact: expected format '{FORMAT}', found '{found}'")]
    Format { found: String },
    #[error(
        "unsupported artifact format version {found} (this build reads \
         JSON versions {MIN_VERSION}..={VERSION} and binary container \
         version {BIN_VERSION})"
    )]
    Version { found: i64 },
    #[error(
        "dtype '{dtype}' requires artifact format version {needs}, but this \
         document declares version {found} — the file is forged or spliced"
    )]
    DtypeVersion { dtype: String, needs: i64, found: i64 },
    #[error(
        "artifact checksum mismatch: stored {stored}, computed {computed} — \
         the file is corrupted or was edited by hand"
    )]
    Checksum { stored: String, computed: String },
    #[error("malformed artifact model: {0}")]
    Model(String),
    #[error("malformed binary artifact: {0}")]
    Binary(String),
    #[error("precision re-proof failed on load: {0}")]
    Precision(#[from] ShapeError),
    #[error(
        "artifact failed the static soundness check [{rule}] at node \
         '{node}': {detail} (checksum-valid file, adversarial or corrupt \
         model content)"
    )]
    Unsound {
        rule: &'static str,
        node: String,
        detail: String,
    },
}

/// Identity of a loaded artifact file, surfaced alongside the decoded
/// model so a serving registry can record exactly which bytes a model
/// name is serving (and an operator can audit a hot swap after the
/// fact). The checksum is the artifact's own stored (and verified)
/// FNV-1a 64 model digest.
#[derive(Clone, Debug)]
pub struct ArtifactProvenance {
    pub path: String,
    pub checksum: String,
    pub format_version: i64,
    pub bytes: u64,
}

/// A deserialization-ready image of a deployed model: the integer graph
/// with its precision stamps, the per-layer quantization table, per-node
/// eps / worst-case diagnostics, and the pipeline stage metadata. The QD
/// float twin is deliberately NOT shipped — the artifact is the paper's
/// float-free integer program, nothing else.
#[derive(Clone, Debug)]
pub struct DeployedArtifact {
    pub graph: IntGraph,
    pub layers: Vec<LayerQuant>,
    pub node_eps: Vec<f64>,
    pub worst_case: Vec<i64>,
    pub meta: StageMeta,
}

impl DeployedArtifact {
    /// Snapshot a deployment record (plus its stage metadata) for saving.
    pub fn from_deployed(dep: &Deployed, meta: &StageMeta) -> Self {
        DeployedArtifact {
            graph: dep.id.clone(),
            layers: dep.layers.clone(),
            node_eps: dep.node_eps.clone(),
            worst_case: dep.worst_case.clone(),
            meta: meta.clone(),
        }
    }

    /// Quantum of the model's input space (from the Input node spec).
    pub fn eps_in(&self) -> f64 {
        self.graph
            .nodes
            .iter()
            .find_map(|n| match &n.op {
                IntOp::Input { spec, .. } => Some(spec.eps),
                _ => None,
            })
            .unwrap_or(1.0 / 255.0)
    }

    /// Release the integer graph (for executor construction).
    pub fn into_int_graph(self) -> IntGraph {
        self.graph
    }

    /// Reassemble a [`Deployed`] record. The QD float twin is not part
    /// of the artifact, so `Deployed::qd` comes back as an *empty* float
    /// graph — the integer program is complete, float diagnostics that
    /// need the twin (e.g. per-node QD-vs-ID comparison) are not
    /// available on a loaded model.
    pub fn into_deployed(self) -> (Deployed, StageMeta) {
        let eps_in = self.eps_in();
        let eps_out = self.graph.eps_out;
        let meta = self.meta;
        let dep = Deployed {
            qd: Graph::new(eps_in),
            id: self.graph,
            layers: self.layers,
            eps_out,
            worst_case: self.worst_case,
            node_eps: self.node_eps,
        };
        (dep, meta)
    }

    /// Serialize to the versioned, checksummed artifact document.
    pub fn to_json(&self) -> Value {
        doc_of(model_value(
            &self.graph,
            &self.layers,
            &self.node_eps,
            &self.worst_case,
            &self.meta,
        ))
    }

    /// Write `model.nemo.json` to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), ArtifactError> {
        write_doc(&self.to_json(), path.as_ref())
    }

    /// Serialize straight from a borrowed deployment record — the
    /// `Network::save_deployed` path. Unlike [`Self::from_deployed`] +
    /// [`Self::save`], this never clones the weight tensors, so saving
    /// a large model does not double its peak memory.
    pub fn save_parts(
        dep: &Deployed,
        meta: &StageMeta,
        path: impl AsRef<Path>,
    ) -> Result<(), ArtifactError> {
        let doc = doc_of(model_value(
            &dep.id,
            &dep.layers,
            &dep.node_eps,
            &dep.worst_case,
            meta,
        ));
        write_doc(&doc, path.as_ref())
    }

    /// Write the v3 binary container (`model.nemob`) to `path`.
    pub fn save_binary(&self, path: impl AsRef<Path>) -> Result<(), ArtifactError> {
        save_binary_graph(
            &self.graph,
            &self.layers,
            &self.node_eps,
            &self.worst_case,
            &self.meta,
            path.as_ref(),
        )
    }

    /// Binary twin of [`Self::save_parts`]: serialize the v3 container
    /// straight from a borrowed deployment record, never cloning the
    /// weight tensors.
    pub fn save_binary_parts(
        dep: &Deployed,
        meta: &StageMeta,
        path: impl AsRef<Path>,
    ) -> Result<(), ArtifactError> {
        save_binary_graph(
            &dep.id,
            &dep.layers,
            &dep.node_eps,
            &dep.worst_case,
            meta,
            path.as_ref(),
        )
    }

    /// Load and fully validate an artifact: format/version gate, checksum
    /// over the model subtree, structural graph validation, payload
    /// range checks and the precision re-proof. Accepts either on-disk
    /// form — the first 8 bytes decide (the [`BIN_MAGIC`] preamble vs a
    /// JSON document).
    pub fn load(path: impl AsRef<Path>) -> Result<Self, ArtifactError> {
        Self::load_with_provenance(path).map(|(art, _)| art)
    }

    /// [`Self::load`], additionally returning the file's
    /// [`ArtifactProvenance`] (path, verified checksum, format version,
    /// byte size) for registries and tooling that must report *which*
    /// artifact a model came from.
    pub fn load_with_provenance(
        path: impl AsRef<Path>,
    ) -> Result<(Self, ArtifactProvenance), ArtifactError> {
        if sniff_binary(path.as_ref())? {
            return Self::load_binary(path, BinLoadMode::Auto)
                .map(|(art, prov, _)| (art, prov));
        }
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|source| {
            ArtifactError::Io { path: path.display().to_string(), source }
        })?;
        let doc = json::parse(&text)?;
        let art = Self::from_text(&text, &doc)?;
        // from_text validated format/version/checksum, so these reads
        // cannot fail — but route errors anyway rather than unwrap.
        let prov = ArtifactProvenance {
            path: path.display().to_string(),
            checksum: doc.get("checksum")?.as_str()?.to_string(),
            format_version: doc.get("version")?.as_i64()?,
            bytes: text.len() as u64,
        };
        Ok((art, prov))
    }

    /// Load the v3 binary container, additionally returning the
    /// [`BinLoadStats`] borrowed/copied accounting that proves (or
    /// refutes) the zero-copy contract for this load.
    pub fn load_binary(
        path: impl AsRef<Path>,
        mode: BinLoadMode,
    ) -> Result<(Self, ArtifactProvenance, BinLoadStats), ArtifactError> {
        load_binary_impl(path.as_ref(), mode)
    }

    /// [`Self::load`] followed by the static soundness verifier
    /// (`analysis::check_graph`) under the given [`CheckMode`]: `Off`
    /// keeps the historic decode-only contract, `Warn` prints findings
    /// to stderr and loads anyway, `Strict` rejects on any
    /// error-severity finding — the gate that keeps a checksum-valid
    /// artifact with adversarial weights out of the engines.
    pub fn load_checked(
        path: impl AsRef<Path>,
        mode: CheckMode,
    ) -> Result<Self, ArtifactError> {
        Self::load_with_provenance_checked(path, mode).map(|(art, _)| art)
    }

    /// [`Self::load_with_provenance`] plus the [`CheckMode`] gate of
    /// [`Self::load_checked`].
    pub fn load_with_provenance_checked(
        path: impl AsRef<Path>,
        mode: CheckMode,
    ) -> Result<(Self, ArtifactProvenance), ArtifactError> {
        let (art, prov) = Self::load_with_provenance(path)?;
        art.run_check(mode, &prov.path)?;
        Ok((art, prov))
    }

    /// [`Self::load_binary`] plus the [`CheckMode`] gate of
    /// [`Self::load_checked`].
    pub fn load_binary_checked(
        path: impl AsRef<Path>,
        mode: BinLoadMode,
        check: CheckMode,
    ) -> Result<(Self, ArtifactProvenance, BinLoadStats), ArtifactError> {
        let (art, prov, stats) = load_binary_impl(path.as_ref(), mode)?;
        art.run_check(check, &prov.path)?;
        Ok((art, prov, stats))
    }

    /// Run the static verifier over the decoded graph and apply the
    /// [`CheckMode`] policy (see DESIGN.md §Static-verification).
    pub fn run_check(&self, mode: CheckMode, origin: &str) -> Result<(), ArtifactError> {
        if mode == CheckMode::Off {
            return Ok(());
        }
        let report = crate::analysis::check_graph(&self.graph);
        for f in &report.findings {
            if mode == CheckMode::Warn || f.severity != Severity::Error {
                eprintln!(
                    "nemo check [{origin}]: {} [{}] '{}': {}",
                    f.severity.name(),
                    f.rule,
                    f.name,
                    f.message
                );
            }
        }
        if mode == CheckMode::Strict {
            if let Some(f) = report.first_error() {
                return Err(ArtifactError::Unsound {
                    rule: f.rule,
                    node: f.name.clone(),
                    detail: f.message.clone(),
                });
            }
        }
        Ok(())
    }

    /// Decode a parsed artifact document (the inverse of [`Self::to_json`]).
    pub fn from_json(v: &Value) -> Result<Self, ArtifactError> {
        Self::decode_doc(v, |model| {
            let computed = checksum_of(model);
            (computed == v.get("checksum").and_then(|c| c.as_str()).unwrap_or(""), computed)
        })
    }

    /// [`Self::from_json`] with the read-once checksum: hash the raw
    /// byte span of the `model` subtree inside `text` (located by a
    /// token-level scan, no re-serialization) and only fall back to the
    /// canonical re-serialize when the raw span does not reproduce the
    /// stored digest — e.g. a hand-reformatted but intact file.
    fn from_text(text: &str, v: &Value) -> Result<Self, ArtifactError> {
        Self::decode_doc(v, |model| {
            let stored = v.get("checksum").and_then(|c| c.as_str()).unwrap_or("");
            if let Some((s, e)) = json::top_level_value_span(text, "model") {
                if checksum_of_bytes(text[s..e].as_bytes()) == stored {
                    return (true, stored.to_string());
                }
            }
            let computed = checksum_of(model);
            (computed == stored, computed)
        })
    }

    fn decode_doc(
        v: &Value,
        verify: impl FnOnce(&Value) -> (bool, String),
    ) -> Result<Self, ArtifactError> {
        let found = v
            .get_opt("format")
            .and_then(|f| f.as_str().ok())
            .unwrap_or("<missing>")
            .to_string();
        if found != FORMAT {
            return Err(ArtifactError::Format { found });
        }
        let version = v.get("version")?.as_i64()?;
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(ArtifactError::Version { found: version });
        }
        let stored = v.get("checksum")?.as_str()?.to_string();
        let model = v.get("model")?;
        let (ok, computed) = verify(model);
        if !ok {
            return Err(ArtifactError::Checksum { stored, computed });
        }
        decode_model(model, version, &mut None)
    }
}

/// Does `path` start with the v3 container magic? Missing files and
/// short JSON files route through the JSON loader for its (better)
/// error reporting.
fn sniff_binary(path: &Path) -> Result<bool, ArtifactError> {
    use std::io::Read;
    let Ok(mut f) = std::fs::File::open(path) else {
        return Ok(false);
    };
    let mut magic = [0u8; 8];
    match f.read_exact(&mut magic) {
        Ok(()) => Ok(magic == BIN_MAGIC),
        Err(_) => Ok(false),
    }
}

// -- encoding ---------------------------------------------------------

/// Wrap a model subtree in the versioned, checksummed document.
fn doc_of(model: Value) -> Value {
    let checksum = checksum_of(&model);
    json::obj(vec![
        ("format", Value::Str(FORMAT.to_string())),
        ("version", Value::Int(VERSION)),
        ("checksum", Value::Str(checksum)),
        ("model", model),
    ])
}

fn write_doc(doc: &Value, path: &Path) -> Result<(), ArtifactError> {
    std::fs::write(path, json::write(doc)).map_err(|source| ArtifactError::Io {
        path: path.display().to_string(),
        source,
    })
}

fn model_value(
    graph: &IntGraph,
    layers: &[LayerQuant],
    node_eps: &[f64],
    worst_case: &[i64],
    meta: &StageMeta,
) -> Value {
    model_value_with(graph, layers, node_eps, worst_case, meta, &mut |_, wq| {
        weight_value(&wq.widen())
    })
}

/// [`model_value`] with a pluggable weight encoder: the JSON form
/// inlines every payload ([`weight_value`]), the binary form routes it
/// into the section table and emits a `{dtype, shape, section}` ref.
fn model_value_with(
    graph: &IntGraph,
    layers: &[LayerQuant],
    node_eps: &[f64],
    worst_case: &[i64],
    meta: &StageMeta,
    enc_weight: &mut dyn FnMut(&str, &QTensor) -> Value,
) -> Value {
    let nodes: Vec<Value> =
        graph.nodes.iter().map(|n| node_value(n, enc_weight)).collect();
    json::obj(vec![
        ("eps_out", Value::Num(graph.eps_out)),
        (
            "graph",
            json::obj(vec![
                ("output", Value::Int(graph.output as i64)),
                ("nodes", Value::Arr(nodes)),
            ]),
        ),
        (
            "meta",
            json::obj(vec![
                ("act_betas", json::arr_f64(&meta.act_betas)),
                ("wbits", Value::Int(meta.wbits as i64)),
                ("abits", Value::Int(meta.abits as i64)),
                ("bn_folded", Value::Bool(meta.bn_folded)),
            ]),
        ),
        ("layers", Value::Arr(layers.iter().map(layer_value).collect())),
        ("node_eps", json::arr_f64(node_eps)),
        ("worst_case", json::arr_i64(worst_case)),
    ])
}

/// FNV-1a 64 over a byte slice — the crate's integrity hash. The
/// artifact format uses it over the canonical JSON model subtree; the
/// wire protocol ([`crate::net::protocol`]) uses the same function over
/// every frame payload, so one hash implementation guards both the
/// at-rest and the in-flight representation.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64 over the canonical JSON serialization of the model subtree.
/// The writer is deterministic (BTreeMap key order, exact shortest-float
/// formatting) and numbers round-trip bit-exactly, so parse → re-write →
/// hash reproduces the saved checksum on an intact file.
fn checksum_of(model: &Value) -> String {
    checksum_of_bytes(json::write(model).as_bytes())
}

fn checksum_of_bytes(bytes: &[u8]) -> String {
    format!("fnv1a64:{:016x}", fnv1a64(bytes))
}

fn usize_arr_value(v: &[usize]) -> Value {
    Value::Arr(v.iter().map(|x| Value::Int(*x as i64)).collect())
}

fn i32_arr_value(v: &[i32]) -> Value {
    Value::Arr(v.iter().map(|x| Value::Int(*x as i64)).collect())
}

fn requant_value(rq: &Requant) -> Value {
    json::obj(vec![
        ("m", Value::Int(rq.m)),
        ("d", Value::Int(rq.d as i64)),
        ("lo", Value::Int(rq.lo)),
        ("hi", Value::Int(rq.hi)),
    ])
}

/// Lowercase hex of a packed byte payload (the JSON-safe carrier for
/// bit-packed weight sections — 2 characters per byte, so a 4-bit grid
/// still lands at 1 character per weight vs ~4 for the int array form).
fn hex_of(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn bytes_of_hex(s: &str, what: &str) -> Result<Vec<u8>, ArtifactError> {
    if s.len() % 2 != 0 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(model_err(format!("{what}: malformed hex payload")));
    }
    Ok(s.as_bytes()
        .chunks_exact(2)
        .map(|c| {
            u8::from_str_radix(std::str::from_utf8(c).unwrap(), 16).unwrap()
        })
        .collect())
}

/// Weight payload at its packed precision: the tightest storage class
/// containing the data range, tagged so the loader re-narrows (and
/// thereby range-checks) the payload. Sub-byte grids (format v2) ship a
/// hex-encoded bit-packed `packed` field at 2–8 weights per byte;
/// byte-and-wider grids keep the v1 `data` int array.
fn weight_value(wq: &TensorI) -> Value {
    let lo = wq.data().iter().copied().min().unwrap_or(0) as i64;
    let hi = wq.data().iter().copied().max().unwrap_or(0) as i64;
    let p = Precision::for_range(lo, hi);
    let mut fields = vec![
        ("dtype", Value::Str(p.name().to_string())),
        ("shape", usize_arr_value(wq.shape())),
    ];
    if p.is_sub_byte() {
        let q = QTensor::narrow_from(wq, p).expect("range-derived precision");
        let packed = match &q {
            QTensor::Packed(t) => hex_of(t.bytes()),
            _ => unreachable!("sub-byte precisions narrow to packed payloads"),
        };
        fields.push(("packed", Value::Str(packed)));
    } else {
        fields.push(("data", i32_arr_value(wq.data())));
    }
    json::obj(fields)
}

/// Re-narrow a graph weight to the tightest storage class containing
/// its range — the representation both artifact forms ship. A weight
/// already stored at that class is reused as-is (no copy).
fn narrow_weight(wq: &QTensor) -> QTensor {
    let (lo, hi) = wq.min_max();
    let p = Precision::for_range(lo, hi);
    if wq.precision() == p {
        return wq.clone();
    }
    QTensor::narrow_from(&wq.widen(), p).expect("range-derived precision")
}

/// The section payload: exactly the in-memory packed bytes (`i32`
/// little-endian so the file is host-independent; on little-endian
/// hosts — every deployment target — the loader views it in place).
fn payload_bytes(q: &QTensor) -> Vec<u8> {
    match q {
        QTensor::U8(t) => t.data().to_vec(),
        QTensor::I8(t) => t.data().iter().map(|v| *v as u8).collect(),
        QTensor::I32(t) => t.data().iter().flat_map(|v| v.to_le_bytes()).collect(),
        QTensor::Packed(t) => t.bytes().to_vec(),
    }
}

/// Accumulates the v3 section table while the model subtree is being
/// encoded: every GEMM weight becomes one 64-byte-aligned, checksummed
/// section, and the model carries a `{dtype, shape, section}` ref.
#[derive(Default)]
struct SectionBuilder {
    entries: Vec<Value>,
    offs: Vec<usize>,
    payloads: Vec<Vec<u8>>,
}

impl SectionBuilder {
    fn push(&mut self, name: &str, wq: &QTensor) -> Value {
        let q = narrow_weight(wq);
        let p = q.precision();
        let payload = payload_bytes(&q);
        let off = match (self.offs.last(), self.payloads.last()) {
            (Some(o), Some(pl)) => align_up(o + pl.len()),
            _ => 0,
        };
        let idx = self.payloads.len();
        self.entries.push(json::obj(vec![
            ("bytes", Value::Int(payload.len() as i64)),
            ("checksum", Value::Str(checksum_of_bytes(&payload))),
            ("dtype", Value::Str(p.name().to_string())),
            ("name", Value::Str(name.to_string())),
            ("off", Value::Int(off as i64)),
            ("shape", usize_arr_value(q.shape())),
        ]));
        self.offs.push(off);
        self.payloads.push(payload);
        json::obj(vec![
            ("dtype", Value::Str(p.name().to_string())),
            ("section", Value::Int(idx as i64)),
            ("shape", usize_arr_value(q.shape())),
        ])
    }
}

fn save_binary_graph(
    graph: &IntGraph,
    layers: &[LayerQuant],
    node_eps: &[f64],
    worst_case: &[i64],
    meta: &StageMeta,
    path: &Path,
) -> Result<(), ArtifactError> {
    let mut sb = SectionBuilder::default();
    let model = model_value_with(graph, layers, node_eps, worst_case, meta, &mut |name, wq| {
        sb.push(name, wq)
    });
    let checksum = checksum_of(&model);
    let header = json::obj(vec![
        ("checksum", Value::Str(checksum)),
        ("format", Value::Str(FORMAT.to_string())),
        ("model", model),
        ("sections", Value::Arr(sb.entries)),
        ("version", Value::Int(BIN_VERSION as i64)),
    ]);
    let htext = json::write(&header);
    if u32::try_from(htext.len()).is_err() {
        return Err(ArtifactError::Binary(format!(
            "header is {} bytes, the u32 length field caps it at 4 GiB",
            htext.len()
        )));
    }
    // Section offsets are relative to the payload base, which only
    // depends on the header length *after* the header is final — no
    // circularity between table and header size.
    let payload_base = align_up(16 + htext.len());
    let end = match (sb.offs.last(), sb.payloads.last()) {
        (Some(o), Some(p)) => o + p.len(),
        _ => 0,
    };
    let mut file = vec![0u8; payload_base + end];
    file[..8].copy_from_slice(&BIN_MAGIC);
    file[8..12].copy_from_slice(&BIN_VERSION.to_le_bytes());
    file[12..16].copy_from_slice(&(htext.len() as u32).to_le_bytes());
    file[16..16 + htext.len()].copy_from_slice(htext.as_bytes());
    for (off, payload) in sb.offs.iter().zip(&sb.payloads) {
        let at = payload_base + off;
        file[at..at + payload.len()].copy_from_slice(payload);
    }
    std::fs::write(path, &file).map_err(|source| ArtifactError::Io {
        path: path.display().to_string(),
        source,
    })
}

fn node_value(
    n: &crate::graph::int::IntNode,
    enc_weight: &mut dyn FnMut(&str, &QTensor) -> Value,
) -> Value {
    let params = match &n.op {
        IntOp::Input { shape, spec } => json::obj(vec![
            ("shape", usize_arr_value(shape)),
            ("eps", Value::Num(spec.eps)),
            ("lo", Value::Int(spec.lo)),
            ("hi", Value::Int(spec.hi)),
        ]),
        IntOp::ConvInt { wq, bias_q, cin, kh, kw, stride, pad } => {
            let mut fields = vec![
                ("w", enc_weight(&n.name, wq)),
                ("cin", Value::Int(*cin as i64)),
                ("kh", Value::Int(*kh as i64)),
                ("kw", Value::Int(*kw as i64)),
                ("stride", Value::Int(*stride as i64)),
                ("pad", Value::Int(*pad as i64)),
            ];
            if let Some(b) = bias_q {
                fields.push(("bias", json::arr_i64(b)));
            }
            json::obj(fields)
        }
        IntOp::LinearInt { wq, bias_q } => {
            let mut fields = vec![("w", enc_weight(&n.name, wq))];
            if let Some(b) = bias_q {
                fields.push(("bias", json::arr_i64(b)));
            }
            json::obj(fields)
        }
        IntOp::IntBn { bn } => json::obj(vec![
            ("kappa_q", i32_arr_value(&bn.kappa_q)),
            ("lambda_q", i32_arr_value(&bn.lambda_q)),
            ("eps_kappa", Value::Num(bn.eps_kappa)),
            ("eps_phi_out", Value::Num(bn.eps_phi_out)),
        ]),
        IntOp::RequantAct { rq } => requant_value(rq),
        IntOp::ThreshAct { th } => json::obj(vec![
            ("n_levels", Value::Int(th.n_levels)),
            (
                "th",
                Value::Arr(th.th.iter().map(|c| json::arr_i64(c)).collect()),
            ),
        ]),
        IntOp::AvgPoolInt { k, d } => json::obj(vec![
            ("k", Value::Int(*k as i64)),
            ("d", Value::Int(*d as i64)),
        ]),
        IntOp::MaxPoolInt { k } => json::obj(vec![("k", Value::Int(*k as i64))]),
        IntOp::Flatten => json::obj(vec![]),
        IntOp::AddRequant { rqs } => json::obj(vec![(
            "rqs",
            Value::Arr(rqs.iter().map(requant_value).collect()),
        )]),
    };
    json::obj(vec![
        ("name", Value::Str(n.name.clone())),
        ("op", Value::Str(n.op.name().to_string())),
        (
            "inputs",
            Value::Arr(n.inputs.iter().map(|i| Value::Int(*i as i64)).collect()),
        ),
        ("precision", Value::Str(n.precision.name().to_string())),
        ("params", params),
    ])
}

fn layer_value(l: &LayerQuant) -> Value {
    json::obj(vec![
        ("name", Value::Str(l.name.clone())),
        ("beta_w", Value::Num(l.beta_w)),
        ("eps_w", Value::Num(l.eps_w)),
        ("eps_phi", Value::Num(l.eps_phi)),
        ("eps_kappa", Value::Num(l.eps_kappa)),
        ("eps_phi_out", Value::Num(l.eps_phi_out)),
        ("beta_y", Value::Num(l.beta_y)),
        ("eps_y", Value::Num(l.eps_y)),
        ("d", Value::Int(l.d as i64)),
        ("m", Value::Int(l.m)),
        ("act_hi", Value::Int(l.act_hi)),
    ])
}

// -- decoding ---------------------------------------------------------

fn model_err(msg: impl Into<String>) -> ArtifactError {
    ArtifactError::Model(msg.into())
}

fn as_usize(v: &Value, what: &str) -> Result<usize, ArtifactError> {
    let i = v.as_i64()?;
    usize::try_from(i).map_err(|_| model_err(format!("{what}: {i} is negative")))
}

fn usize_arr(v: &Value, what: &str) -> Result<Vec<usize>, ArtifactError> {
    v.as_arr()?.iter().map(|e| as_usize(e, what)).collect()
}

fn i64_arr(v: &Value) -> Result<Vec<i64>, ArtifactError> {
    Ok(v.as_arr()?
        .iter()
        .map(|e| e.as_i64())
        .collect::<Result<Vec<_>, _>>()?)
}

fn i32_arr(v: &Value, what: &str) -> Result<Vec<i32>, ArtifactError> {
    i64_arr(v)?
        .into_iter()
        .map(|x| {
            i32::try_from(x)
                .map_err(|_| model_err(format!("{what}: {x} does not fit i32")))
        })
        .collect()
}

fn f64_arr(v: &Value) -> Result<Vec<f64>, ArtifactError> {
    Ok(v.as_arr()?
        .iter()
        .map(|e| e.as_f64())
        .collect::<Result<Vec<_>, _>>()?)
}

/// A shift width; bounds-checked so a crafted artifact cannot make the
/// engines execute an over-wide (panicking) `>>`.
fn shift_d(v: &Value, what: &str) -> Result<u32, ArtifactError> {
    let d = v.as_i64()?;
    if !(0..=63).contains(&d) {
        return Err(model_err(format!("{what}: shift d = {d} outside 0..=63")));
    }
    Ok(d as u32)
}

fn decode_requant(v: &Value, what: &str) -> Result<Requant, ArtifactError> {
    let rq = Requant {
        m: v.get("m")?.as_i64()?,
        d: shift_d(v.get("d")?, what)?,
        lo: v.get("lo")?.as_i64()?,
        hi: v.get("hi")?.as_i64()?,
    };
    if rq.lo > rq.hi {
        return Err(model_err(format!(
            "{what}: clip range [{}, {}] is empty",
            rq.lo, rq.hi
        )));
    }
    Ok(rq)
}

/// Reject a sub-byte dtype in a document too old to carry one: v1
/// writers could not produce these names, so this is a typed forgery
/// error, not a parse failure.
fn gate_subbyte(
    p: Precision,
    name: &str,
    version: i64,
) -> Result<(), ArtifactError> {
    if p.is_sub_byte() && version < SUBBYTE_VERSION {
        return Err(ArtifactError::DtypeVersion {
            dtype: name.to_string(),
            needs: SUBBYTE_VERSION,
            found: version,
        });
    }
    Ok(())
}

/// Decode a weight payload at its *stored* precision: dtype-tagged
/// flat int array (v1), hex bit-packed payload for sub-byte dtypes
/// (v2), or a `{dtype, shape, section}` reference into a v3 binary
/// section table. Inline payloads are narrowed through
/// [`QTensor::narrow_from`] (loud on any value outside the declared
/// precision) or validated by [`PackedTensor::from_bytes`] (loud on
/// wrong length / dirty pad bits); section refs resolve to zero-copy
/// views over the mapped file. The graph ops carry the result as-is —
/// full-width consumers widen on use.
fn decode_weights(
    v: &Value,
    what: &str,
    version: i64,
    bins: &mut Option<BinSections>,
) -> Result<QTensor, ArtifactError> {
    let dtype = v.get("dtype")?.as_str()?;
    let p = Precision::from_name(dtype)
        .ok_or_else(|| model_err(format!("{what}: unknown weight dtype '{dtype}'")))?;
    gate_subbyte(p, dtype, version)?;
    let shape = usize_arr(v.get("shape")?, what)?;
    if let Some(sec) = v.get_opt("section") {
        let idx = as_usize(sec, what)?;
        let Some(b) = bins.as_mut() else {
            return Err(model_err(format!(
                "{what}: weight references binary section {idx} in a JSON artifact"
            )));
        };
        return b.take(idx, p, &shape, what);
    }
    if p.is_sub_byte() {
        let hex = v.get("packed")?.as_str()?;
        let data = bytes_of_hex(hex, what)?;
        let t = PackedTensor::from_bytes(&shape, p, data)
            .map_err(|e| model_err(format!("{what}: weight payload {e}")))?;
        return Ok(QTensor::Packed(t));
    }
    let data = i32_arr(v.get("data")?, what)?;
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(model_err(format!(
            "{what}: shape {shape:?} wants {n} values, payload has {}",
            data.len()
        )));
    }
    let t = Tensor::from_vec(&shape, data);
    QTensor::narrow_from(&t, p)
        .map_err(|e| model_err(format!("{what}: weight payload {e}")))
}

fn decode_op(
    op: &str,
    p: &Value,
    what: &str,
    version: i64,
    bins: &mut Option<BinSections>,
) -> Result<IntOp, ArtifactError> {
    Ok(match op {
        "Input" => {
            let spec = QuantSpec {
                eps: p.get("eps")?.as_f64()?,
                lo: p.get("lo")?.as_i64()?,
                hi: p.get("hi")?.as_i64()?,
            };
            if !spec.eps.is_finite() || spec.eps <= 0.0 {
                return Err(model_err(format!(
                    "{what}: input eps {} must be a positive finite value",
                    spec.eps
                )));
            }
            if spec.lo > spec.hi {
                return Err(model_err(format!(
                    "{what}: input range [{}, {}] is empty",
                    spec.lo, spec.hi
                )));
            }
            IntOp::Input { shape: usize_arr(p.get("shape")?, what)?, spec }
        }
        "ConvInt" => IntOp::ConvInt {
            wq: decode_weights(p.get("w")?, what, version, bins)?,
            bias_q: p.get_opt("bias").map(i64_arr).transpose()?,
            cin: as_usize(p.get("cin")?, what)?,
            kh: as_usize(p.get("kh")?, what)?,
            kw: as_usize(p.get("kw")?, what)?,
            stride: as_usize(p.get("stride")?, what)?,
            pad: as_usize(p.get("pad")?, what)?,
        },
        "LinearInt" => IntOp::LinearInt {
            wq: decode_weights(p.get("w")?, what, version, bins)?,
            bias_q: p.get_opt("bias").map(i64_arr).transpose()?,
        },
        "IntBn" => {
            let kappa_q = i32_arr(p.get("kappa_q")?, what)?;
            let lambda_q = i32_arr(p.get("lambda_q")?, what)?;
            if kappa_q.len() != lambda_q.len() {
                return Err(model_err(format!(
                    "{what}: kappa_q ({}) and lambda_q ({}) lengths differ",
                    kappa_q.len(),
                    lambda_q.len()
                )));
            }
            IntOp::IntBn {
                bn: BnQuant {
                    kappa_q,
                    lambda_q,
                    eps_kappa: p.get("eps_kappa")?.as_f64()?,
                    eps_phi_out: p.get("eps_phi_out")?.as_f64()?,
                },
            }
        }
        "RequantAct" => IntOp::RequantAct { rq: decode_requant(p, what)? },
        "ThreshAct" => {
            let n_levels = p.get("n_levels")?.as_i64()?;
            let th: Vec<Vec<i64>> = p
                .get("th")?
                .as_arr()?
                .iter()
                .map(i64_arr)
                .collect::<Result<_, _>>()?;
            for (c, t) in th.iter().enumerate() {
                if t.len() as i64 != n_levels {
                    return Err(model_err(format!(
                        "{what}: channel {c} has {} thresholds, n_levels = {n_levels}",
                        t.len()
                    )));
                }
                if t.windows(2).any(|w| w[0] > w[1]) {
                    return Err(model_err(format!(
                        "{what}: channel {c} thresholds are not ascending"
                    )));
                }
            }
            IntOp::ThreshAct { th: Thresholds { th, n_levels } }
        }
        "AvgPoolInt" => IntOp::AvgPoolInt {
            k: as_usize(p.get("k")?, what)?,
            d: shift_d(p.get("d")?, what)?,
        },
        "MaxPoolInt" => IntOp::MaxPoolInt { k: as_usize(p.get("k")?, what)? },
        "Flatten" => IntOp::Flatten,
        "AddRequant" => IntOp::AddRequant {
            rqs: p
                .get("rqs")?
                .as_arr()?
                .iter()
                .map(|r| decode_requant(r, what))
                .collect::<Result<_, _>>()?,
        },
        other => return Err(model_err(format!("{what}: unknown op '{other}'"))),
    })
}

fn decode_model(
    model: &Value,
    version: i64,
    bins: &mut Option<BinSections>,
) -> Result<DeployedArtifact, ArtifactError> {
    let graph_v = model.get("graph")?;
    let nodes_v = graph_v.get("nodes")?.as_arr()?;
    if nodes_v.is_empty() {
        return Err(model_err("graph has no nodes"));
    }
    let mut graph = IntGraph::default();
    let mut stamps: Vec<Precision> = Vec::with_capacity(nodes_v.len());
    for (idx, nv) in nodes_v.iter().enumerate() {
        let name = nv.get("name")?.as_str()?.to_string();
        let what = format!("node {idx} '{name}'");
        let inputs = usize_arr(nv.get("inputs")?, &what)?;
        // Validate before push: IntGraph::push asserts on forward refs,
        // and a corrupt file must produce an error, not a panic.
        if let Some(&bad) = inputs.iter().find(|&&i| i >= idx) {
            return Err(model_err(format!(
                "{what}: input {bad} is a forward or self reference"
            )));
        }
        let op_name = nv.get("op")?.as_str()?;
        let op = decode_op(op_name, nv.get("params")?, &what, version, bins)?;
        let p_name = nv.get("precision")?.as_str()?;
        let p = Precision::from_name(p_name).ok_or_else(|| {
            model_err(format!("{what}: unknown precision '{p_name}'"))
        })?;
        gate_subbyte(p, p_name, version)?;
        graph.push(&name, op, &inputs);
        stamps.push(p);
    }
    for (id, p) in stamps.into_iter().enumerate() {
        graph.stamp_precision(id, p);
    }
    graph.output = as_usize(graph_v.get("output")?, "graph output")?;
    graph.eps_out = model.get("eps_out")?.as_f64()?;
    graph.validate().map_err(ArtifactError::Model)?;
    // Precision re-proof: the stored stamps must still be sound for the
    // reconstructed ops before any packed kernel dispatches on them.
    infer_precision(&graph)?;

    let meta_v = model.get("meta")?;
    let meta = StageMeta {
        act_betas: f64_arr(meta_v.get("act_betas")?)?,
        wbits: meta_v.get("wbits")?.as_i64()? as u32,
        abits: meta_v.get("abits")?.as_i64()? as u32,
        bn_folded: meta_v.get("bn_folded")?.as_bool()?,
    };
    let layers = model
        .get("layers")?
        .as_arr()?
        .iter()
        .enumerate()
        .map(|(i, lv)| decode_layer(lv, i))
        .collect::<Result<Vec<_>, _>>()?;
    let node_eps = f64_arr(model.get("node_eps")?)?;
    let worst_case = i64_arr(model.get("worst_case")?)?;
    if node_eps.len() != graph.nodes.len() {
        return Err(model_err(format!(
            "node_eps has {} entries for {} nodes",
            node_eps.len(),
            graph.nodes.len()
        )));
    }
    // worst_case is per *source-graph* node (the deploy range analysis),
    // so its length legitimately differs from the ID node count — but an
    // empty vector would panic diagnostics like `worst_case.iter().max()`.
    if worst_case.is_empty() {
        return Err(model_err("worst_case range analysis is empty"));
    }
    Ok(DeployedArtifact { graph, layers, node_eps, worst_case, meta })
}

fn decode_layer(lv: &Value, i: usize) -> Result<LayerQuant, ArtifactError> {
    let what = format!("layer {i}");
    Ok(LayerQuant {
        name: lv.get("name")?.as_str()?.to_string(),
        beta_w: lv.get("beta_w")?.as_f64()?,
        eps_w: lv.get("eps_w")?.as_f64()?,
        eps_phi: lv.get("eps_phi")?.as_f64()?,
        eps_kappa: lv.get("eps_kappa")?.as_f64()?,
        eps_phi_out: lv.get("eps_phi_out")?.as_f64()?,
        beta_y: lv.get("beta_y")?.as_f64()?,
        eps_y: lv.get("eps_y")?.as_f64()?,
        d: shift_d(lv.get("d")?, &what)?,
        m: lv.get("m")?.as_i64()?,
        act_hi: lv.get("act_hi")?.as_i64()?,
    })
}

// -- binary container (v3) --------------------------------------------

/// Borrowed/copied accounting of one binary load: the zero-copy
/// contract made checkable. On the mmap path every section backs a
/// tensor view (`copied_bytes == 0`); the only copies the format ever
/// makes are `i32` sections on a big-endian host.
#[derive(Clone, Copy, Debug, Default)]
pub struct BinLoadStats {
    /// Weight bytes served as views borrowing the file mapping.
    pub borrowed_bytes: usize,
    /// Weight bytes copied into owned storage (big-endian fallback).
    pub copied_bytes: usize,
    /// Number of weight sections consumed.
    pub sections: usize,
    /// Whether the file bytes came from `mmap` (vs the aligned read).
    pub mmap: bool,
}

/// One entry of the parsed v3 section table.
#[derive(Clone, Debug)]
pub struct BinSection {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
    /// Offset relative to the payload base; always 64-byte aligned.
    pub off: usize,
    pub bytes: usize,
    pub checksum: String,
}

/// Header-level description of a binary artifact, for `nemo info`.
#[derive(Clone, Debug)]
pub struct BinInfo {
    pub container_version: u32,
    pub header_bytes: usize,
    pub payload_base: usize,
    pub file_bytes: usize,
    /// Sum of raw section payload bytes.
    pub weight_bytes: usize,
    /// Section bytes including the inter-section alignment padding.
    pub aligned_weight_bytes: usize,
    pub checksum: String,
    pub sections: Vec<BinSection>,
}

fn bin_err(msg: impl Into<String>) -> ArtifactError {
    ArtifactError::Binary(msg.into())
}

/// The section table plus everything [`decode_weights`] needs to turn
/// a `{section: idx}` ref into a tensor view: the owning byte source,
/// the payload base, and exactly-once consumption tracking.
struct BinSections {
    src: Arc<dyn ByteSource>,
    payload_base: usize,
    sections: Vec<BinSection>,
    used: Vec<bool>,
    stats: BinLoadStats,
}

impl BinSections {
    fn take(
        &mut self,
        idx: usize,
        p: Precision,
        shape: &[usize],
        what: &str,
    ) -> Result<QTensor, ArtifactError> {
        let Some(sec) = self.sections.get(idx) else {
            return Err(bin_err(format!(
                "{what}: weight references section {idx}, table has {}",
                self.sections.len()
            )));
        };
        if self.used[idx] {
            return Err(bin_err(format!(
                "{what}: section {idx} '{}' consumed twice",
                sec.name
            )));
        }
        self.used[idx] = true;
        if sec.dtype != p.name() || sec.shape != shape {
            return Err(bin_err(format!(
                "{what}: weight ref ({} {shape:?}) disagrees with section {idx} \
                 '{}' ({} {:?})",
                p.name(),
                sec.name,
                sec.dtype,
                sec.shape
            )));
        }
        let len: usize = shape.iter().product();
        if sec.bytes != p.storage_bytes(len) {
            return Err(bin_err(format!(
                "{what}: section {idx} '{}' holds {} bytes, dtype {} with shape \
                 {shape:?} wants {}",
                sec.name,
                sec.bytes,
                p.name(),
                p.storage_bytes(len)
            )));
        }
        let off = self.payload_base + sec.off;
        let q = match p {
            Precision::U8 => Tensor::<u8>::from_view(shape, self.src.clone(), off)
                .map(QTensor::U8)
                .map_err(|e| bin_err(format!("{what}: section {idx}: {e}")))?,
            Precision::I8 => Tensor::<i8>::from_view(shape, self.src.clone(), off)
                .map(QTensor::I8)
                .map_err(|e| bin_err(format!("{what}: section {idx}: {e}")))?,
            Precision::I32 => {
                // from_view rejects multi-byte views on big-endian
                // hosts; decode the little-endian payload there.
                match Tensor::<i32>::from_view(shape, self.src.clone(), off) {
                    Ok(t) => QTensor::I32(t),
                    Err(_) => {
                        let b = &self.src.bytes()[off..off + sec.bytes];
                        let data: Vec<i32> = b
                            .chunks_exact(4)
                            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect();
                        self.stats.copied_bytes += sec.bytes;
                        self.stats.sections += 1;
                        return Ok(QTensor::I32(Tensor::from_vec(shape, data)));
                    }
                }
            }
            _ => PackedTensor::from_view(shape, p, self.src.clone(), off)
                .map(QTensor::Packed)
                .map_err(|e| bin_err(format!("{what}: section {idx}: {e}")))?,
        };
        self.stats.borrowed_bytes += sec.bytes;
        self.stats.sections += 1;
        Ok(q)
    }
}

/// Read the 16-byte preamble; returns `(container_version, header_len)`.
fn parse_preamble(bytes: &[u8]) -> Result<(u32, usize), ArtifactError> {
    if bytes.len() < 16 {
        return Err(bin_err(format!(
            "{} bytes is too short for the 16-byte preamble",
            bytes.len()
        )));
    }
    if bytes[..8] != BIN_MAGIC {
        return Err(bin_err("leading magic is not NEMOBIN".to_string()));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != BIN_VERSION {
        return Err(ArtifactError::Version { found: version as i64 });
    }
    let header_len = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    if bytes.len() < 16 + header_len {
        return Err(bin_err(format!(
            "header claims {header_len} bytes, only {} follow the preamble — \
             truncated file",
            bytes.len() - 16
        )));
    }
    Ok((version, header_len))
}

fn decode_section_entry(v: &Value, i: usize) -> Result<BinSection, ArtifactError> {
    let what = format!("section {i}");
    Ok(BinSection {
        name: v.get("name")?.as_str()?.to_string(),
        dtype: v.get("dtype")?.as_str()?.to_string(),
        shape: usize_arr(v.get("shape")?, &what)?,
        off: as_usize(v.get("off")?, &what)?,
        bytes: as_usize(v.get("bytes")?, &what)?,
        checksum: v.get("checksum")?.as_str()?.to_string(),
    })
}

/// Parse + structurally validate the header and section table common to
/// [`load_binary_impl`] and [`binary_info`]. Returns the parsed header
/// document, the stored model checksum, the payload base and the table.
fn parse_bin_header(
    bytes: &[u8],
) -> Result<(Value, String, usize, Vec<BinSection>), ArtifactError> {
    let (_, header_len) = parse_preamble(bytes)?;
    let htext = std::str::from_utf8(&bytes[16..16 + header_len])
        .map_err(|e| bin_err(format!("header is not UTF-8: {e}")))?;
    let hdoc = json::parse(htext)?;
    let found = hdoc
        .get_opt("format")
        .and_then(|f| f.as_str().ok())
        .unwrap_or("<missing>")
        .to_string();
    if found != FORMAT {
        return Err(ArtifactError::Format { found });
    }
    let hversion = hdoc.get("version")?.as_i64()?;
    if hversion != BIN_VERSION as i64 {
        return Err(bin_err(format!(
            "header declares version {hversion}, container preamble says {BIN_VERSION}"
        )));
    }
    let stored = hdoc.get("checksum")?.as_str()?.to_string();
    // Read-once checksum: hash the model's raw span in the header text.
    let model = hdoc.get("model")?;
    let span_ok = json::top_level_value_span(htext, "model")
        .map(|(s, e)| checksum_of_bytes(htext[s..e].as_bytes()) == stored)
        .unwrap_or(false);
    if !span_ok {
        let computed = checksum_of(model);
        if computed != stored {
            return Err(ArtifactError::Checksum { stored, computed });
        }
    }
    let payload_base = align_up(16 + header_len);
    let sections = hdoc
        .get("sections")?
        .as_arr()?
        .iter()
        .enumerate()
        .map(|(i, v)| decode_section_entry(v, i))
        .collect::<Result<Vec<_>, _>>()?;
    let mut prev_end = 0usize;
    for (i, s) in sections.iter().enumerate() {
        if s.off % BIN_ALIGN != 0 {
            return Err(bin_err(format!(
                "section {i} '{}' offset {} is not {BIN_ALIGN}-byte aligned",
                s.name, s.off
            )));
        }
        if i > 0 && s.off < prev_end {
            return Err(bin_err(format!(
                "section {i} '{}' at [{}, {}) overlaps the previous section",
                s.name,
                s.off,
                s.off + s.bytes
            )));
        }
        let end = payload_base
            .checked_add(s.off)
            .and_then(|b| b.checked_add(s.bytes))
            .ok_or_else(|| bin_err(format!("section {i} '{}' offset overflows", s.name)))?;
        if end > bytes.len() {
            return Err(bin_err(format!(
                "section {i} '{}' ends at byte {end}, file has {} — truncated \
                 mid-section",
                s.name,
                bytes.len()
            )));
        }
        prev_end = s.off + s.bytes;
    }
    Ok((hdoc, stored, payload_base, sections))
}

fn load_binary_impl(
    path: &Path,
    mode: BinLoadMode,
) -> Result<(DeployedArtifact, ArtifactProvenance, BinLoadStats), ArtifactError> {
    let io_err = |source| ArtifactError::Io { path: path.display().to_string(), source };
    let (src, mmapped): (Arc<dyn ByteSource>, bool) = match mode {
        BinLoadMode::Mmap => (Arc::new(MappedFile::map(path).map_err(io_err)?), true),
        BinLoadMode::Read => (Arc::new(AlignedBytes::read_file(path).map_err(io_err)?), false),
        BinLoadMode::Auto => match MappedFile::map(path) {
            Ok(m) => (Arc::new(m), true),
            Err(_) => (Arc::new(AlignedBytes::read_file(path).map_err(io_err)?), false),
        },
    };
    let bytes = src.bytes();
    let file_len = bytes.len();
    let (hdoc, stored, payload_base, sections) = parse_bin_header(bytes)?;
    // Per-section integrity before any view is built: a flipped weight
    // byte is a checksum error naming the section, never a wrong logit.
    for (i, s) in sections.iter().enumerate() {
        let payload = &bytes[payload_base + s.off..payload_base + s.off + s.bytes];
        let computed = checksum_of_bytes(payload);
        if computed != s.checksum {
            return Err(ArtifactError::Checksum {
                stored: format!("section {i} '{}': {}", s.name, s.checksum),
                computed,
            });
        }
    }
    let n = sections.len();
    let mut bins = Some(BinSections {
        src: src.clone(),
        payload_base,
        sections,
        used: vec![false; n],
        stats: BinLoadStats { mmap: mmapped, ..Default::default() },
    });
    let art = decode_model(hdoc.get("model")?, BIN_VERSION as i64, &mut bins)?;
    let b = bins.take().expect("decode_model keeps the section context");
    if let Some(idx) = b.used.iter().position(|u| !u) {
        return Err(bin_err(format!(
            "section {idx} '{}' is not referenced by the model — \
             header/section-table mismatch",
            b.sections[idx].name
        )));
    }
    let prov = ArtifactProvenance {
        path: path.display().to_string(),
        checksum: stored,
        format_version: BIN_VERSION as i64,
        bytes: file_len as u64,
    };
    Ok((art, prov, b.stats))
}

/// Header-only inspection of a `model.nemob` (for `nemo info`): the
/// section table and size breakdown, without decoding the model or
/// touching (most of) the weight bytes.
pub fn binary_info(path: impl AsRef<Path>) -> Result<BinInfo, ArtifactError> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).map_err(|source| ArtifactError::Io {
        path: path.display().to_string(),
        source,
    })?;
    let (version, header_len) = parse_preamble(&bytes)?;
    let (_, stored, payload_base, sections) = parse_bin_header(&bytes)?;
    let weight_bytes: usize = sections.iter().map(|s| s.bytes).sum();
    let aligned_weight_bytes = sections
        .last()
        .map(|s| s.off + s.bytes)
        .unwrap_or(0);
    Ok(BinInfo {
        container_version: version,
        header_bytes: header_len,
        payload_base,
        file_bytes: bytes.len(),
        weight_bytes,
        aligned_weight_bytes,
        checksum: stored,
        sections,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mlp;
    use crate::network::Network;
    use crate::quant::quantize_input;
    use crate::tensor::TensorF;
    use crate::transform::DeployOptions;
    use crate::util::rng::Rng;

    fn deployed_mlp(seed: u64) -> (Deployed, StageMeta, TensorF) {
        let mut rng = Rng::new(seed);
        let g = mlp(&mut rng, 12, 10, 4, 1.0 / 255.0);
        let x = TensorF::from_vec(
            &[3, 12],
            (0..36).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        );
        let fp = Network::from_graph(g).unwrap();
        let betas = fp.calibrate(&[x.clone()]);
        let nid = fp
            .quantize_pact(8, 8, &betas)
            .unwrap()
            .deploy(DeployOptions::default())
            .unwrap()
            .integerize();
        let meta = nid.meta().clone();
        (nid.into_deployed(), meta, x)
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let (dep, meta, x) = deployed_mlp(5);
        let art = DeployedArtifact::from_deployed(&dep, &meta);
        let doc = art.to_json();
        let back = DeployedArtifact::from_json(&doc).unwrap();
        assert_eq!(back.graph.nodes.len(), dep.id.nodes.len());
        assert_eq!(back.graph.precisions(), dep.id.precisions());
        assert_eq!(back.graph.eps_out.to_bits(), dep.id.eps_out.to_bits());
        assert_eq!(back.meta.wbits, meta.wbits);
        assert_eq!(back.layers.len(), dep.layers.len());
        // Bit-identity of the frozen program: same logits on real input.
        let qx = quantize_input(&x, 1.0 / 255.0);
        let want = crate::engine::IntegerEngine::new().run(&dep.id, &qx);
        let got = crate::engine::IntegerEngine::new().run(&back.graph, &qx);
        assert_eq!(want, got);
    }

    #[test]
    fn wrong_format_and_version_are_typed_errors() {
        let (dep, meta, _) = deployed_mlp(6);
        let art = DeployedArtifact::from_deployed(&dep, &meta);
        let doc = art.to_json();
        let mut wrong_fmt = doc.clone();
        if let Value::Obj(o) = &mut wrong_fmt {
            o.insert("format".into(), Value::Str("something-else".into()));
        }
        assert!(matches!(
            DeployedArtifact::from_json(&wrong_fmt),
            Err(ArtifactError::Format { .. })
        ));
        let mut wrong_ver = doc;
        if let Value::Obj(o) = &mut wrong_ver {
            o.insert("version".into(), Value::Int(VERSION + 1));
        }
        assert!(matches!(
            DeployedArtifact::from_json(&wrong_ver),
            Err(ArtifactError::Version { found }) if found == VERSION + 1
        ));
    }

    #[test]
    fn tampered_model_fails_the_checksum() {
        let (dep, meta, _) = deployed_mlp(7);
        let art = DeployedArtifact::from_deployed(&dep, &meta);
        let mut doc = art.to_json();
        if let Value::Obj(o) = &mut doc {
            let model = o.get_mut("model").unwrap();
            if let Value::Obj(m) = model {
                m.insert("eps_out".into(), Value::Num(0.5));
            }
        }
        assert!(matches!(
            DeployedArtifact::from_json(&doc),
            Err(ArtifactError::Checksum { .. })
        ));
    }

    #[test]
    fn weight_payloads_are_packed_and_range_checked() {
        let (dep, meta, _) = deployed_mlp(8);
        let art = DeployedArtifact::from_deployed(&dep, &meta);
        let doc = art.to_json();
        // 8-bit weight grids must ship as sub-word payloads.
        let nodes = doc
            .get("model")
            .unwrap()
            .get("graph")
            .unwrap()
            .get("nodes")
            .unwrap()
            .as_arr()
            .unwrap();
        let mut saw_weight = false;
        for n in nodes {
            if let Some(w) = n.get("params").unwrap().get_opt("w") {
                saw_weight = true;
                let dtype = w.get("dtype").unwrap().as_str().unwrap();
                assert_ne!(dtype, "i32", "8-bit weight grid stored wide");
            }
        }
        assert!(saw_weight, "mlp must contain weight payloads");
        // A payload value outside the declared sub-word dtype is loud.
        let mut doc2 = art.to_json();
        let model = match &mut doc2 {
            Value::Obj(o) => o.get_mut("model").unwrap(),
            _ => unreachable!(),
        };
        // Corrupt one weight value inside the declared i8 payload, then
        // re-stamp the checksum so only the payload check can fire.
        fn first_weight_data(model: &mut Value) -> &mut Vec<Value> {
            let nodes = match model {
                Value::Obj(m) => match m.get_mut("graph").unwrap() {
                    Value::Obj(g) => match g.get_mut("nodes").unwrap() {
                        Value::Arr(a) => a,
                        _ => unreachable!(),
                    },
                    _ => unreachable!(),
                },
                _ => unreachable!(),
            };
            for n in nodes {
                if let Value::Obj(no) = n {
                    if let Some(Value::Obj(p)) = no.get_mut("params") {
                        if let Some(Value::Obj(w)) = p.get_mut("w") {
                            if let Some(Value::Arr(d)) = w.get_mut("data") {
                                return d;
                            }
                        }
                    }
                }
            }
            panic!("no weight payload found");
        }
        first_weight_data(model)[0] = Value::Int(100_000);
        let checksum = checksum_of(model);
        if let Value::Obj(o) = &mut doc2 {
            o.insert("checksum".into(), Value::Str(checksum));
        }
        let err = DeployedArtifact::from_json(&doc2).unwrap_err();
        assert!(
            matches!(err, ArtifactError::Model(_)),
            "expected payload range error, got {err}"
        );
    }

    #[test]
    fn subbyte_weight_payloads_pack_and_version_gate() {
        // A ternary weight grid lands on the i4 class and ships as a
        // hex bit-packed payload (no int array at all).
        let wq = Tensor::from_vec(&[4, 2], vec![-1, 0, 1, -1, 0, 1, 1, 0]);
        let wv = weight_value(&wq);
        assert_eq!(wv.get("dtype").unwrap().as_str().unwrap(), "i4");
        assert!(wv.get_opt("data").is_none(), "sub-byte grid stored wide");
        let hex = wv.get("packed").unwrap().as_str().unwrap();
        assert_eq!(hex.len(), 8, "8 nibbles = 4 bytes = 8 hex chars");
        // Format v2 decodes it bit-identically...
        let back = decode_weights(&wv, "test", VERSION, &mut None).unwrap();
        assert_eq!(back.widen(), wq);
        // ...a v1 document carrying the same dtype is a typed error...
        assert!(matches!(
            decode_weights(&wv, "test", 1, &mut None),
            Err(ArtifactError::DtypeVersion { needs: 2, found: 1, .. })
        ));
        // ...and a corrupt payload (wrong length / dirty pad bits /
        // non-hex) is loud, not a best-effort parse.
        let mut short = wv.clone();
        if let Value::Obj(o) = &mut short {
            o.insert("packed".into(), Value::Str("ff".into()));
        }
        assert!(matches!(
            decode_weights(&short, "test", VERSION, &mut None),
            Err(ArtifactError::Model(_))
        ));
        let mut junk = wv;
        if let Value::Obj(o) = &mut junk {
            o.insert("packed".into(), Value::Str("zz00zz00".into()));
        }
        assert!(matches!(
            decode_weights(&junk, "test", VERSION, &mut None),
            Err(ArtifactError::Model(_))
        ));
    }

    #[test]
    fn byte_weight_payloads_keep_the_v1_shape() {
        // Byte-and-wider grids must stay readable by format v1: dtype +
        // flat int `data` array, no `packed` field.
        let wq = Tensor::from_vec(&[3], vec![-100, 0, 100]);
        let wv = weight_value(&wq);
        assert_eq!(wv.get("dtype").unwrap().as_str().unwrap(), "i8");
        assert!(wv.get_opt("packed").is_none());
        let back = decode_weights(&wv, "test", MIN_VERSION, &mut None).unwrap();
        assert_eq!(back.widen(), wq);
    }

    #[test]
    fn binary_roundtrip_is_bit_identical_and_zero_copy() {
        let (dep, meta, x) = deployed_mlp(21);
        let art = DeployedArtifact::from_deployed(&dep, &meta);
        let path = std::env::temp_dir()
            .join(format!("nemo_artifact_unit_{}.nemob", std::process::id()));
        art.save_binary(&path).unwrap();

        for mode in [BinLoadMode::Read, BinLoadMode::Auto] {
            let (back, prov, stats) =
                DeployedArtifact::load_binary(&path, mode).unwrap();
            assert_eq!(prov.format_version, BIN_VERSION as i64);
            assert_eq!(back.graph.precisions(), dep.id.precisions());
            // Every weight byte is served as a borrowed view; the only
            // copy path is i32-on-big-endian.
            if cfg!(target_endian = "little") {
                assert_eq!(stats.copied_bytes, 0, "mode {mode:?}");
                assert!(stats.borrowed_bytes > 0);
            }
            assert!(back.graph.nodes.iter().any(|n| match &n.op {
                IntOp::ConvInt { wq, .. } | IntOp::LinearInt { wq, .. } => {
                    wq.is_borrowed()
                }
                _ => false,
            }));
            let qx = quantize_input(&x, 1.0 / 255.0);
            assert_eq!(
                crate::engine::IntegerEngine::new().run(&dep.id, &qx),
                crate::engine::IntegerEngine::new().run(&back.graph, &qx)
            );
        }
        // The generic loader sniffs the magic and returns the same model.
        let (sniffed, prov) = DeployedArtifact::load_with_provenance(&path).unwrap();
        assert_eq!(prov.format_version, BIN_VERSION as i64);
        assert_eq!(sniffed.graph.precisions(), dep.id.precisions());

        // Header-only info agrees with the section table.
        let info = binary_info(&path).unwrap();
        assert_eq!(info.container_version, BIN_VERSION);
        assert!(!info.sections.is_empty());
        assert!(info.weight_bytes <= info.aligned_weight_bytes);
        assert!(info.payload_base % BIN_ALIGN == 0);
        for s in &info.sections {
            assert_eq!(s.off % BIN_ALIGN, 0);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn file_roundtrip_and_corruption() {
        let (dep, meta, x) = deployed_mlp(9);
        let art = DeployedArtifact::from_deployed(&dep, &meta);
        let path = std::env::temp_dir()
            .join(format!("nemo_artifact_unit_{}.nemo.json", std::process::id()));
        art.save(&path).unwrap();
        let back = DeployedArtifact::load(&path).unwrap();
        let qx = quantize_input(&x, 1.0 / 255.0);
        assert_eq!(
            crate::engine::IntegerEngine::new().run(&dep.id, &qx),
            crate::engine::IntegerEngine::new().run(&back.graph, &qx)
        );
        // Flip one byte inside the model payload: load must fail loudly.
        let mut text = std::fs::read_to_string(&path).unwrap();
        let pos = text.find("\"worst_case\":[").unwrap() + "\"worst_case\":[".len();
        let orig = text.as_bytes()[pos];
        let repl = if orig == b'1' { '2' } else { '1' };
        text.replace_range(pos..pos + 1, &repl.to_string());
        std::fs::write(&path, &text).unwrap();
        assert!(matches!(
            DeployedArtifact::load(&path),
            Err(ArtifactError::Checksum { .. })
        ));
        let _ = std::fs::remove_file(&path);
    }
}
