//! Trainable-parameter enumeration and gradient containers for native
//! training (DESIGN.md §Training).
//!
//! The optimizer flattens every trainable parameter of a [`Graph`] into
//! one `f64` master vector ([`gather_params`] / [`scatter_params`]),
//! steps it with SGD, and writes it back — fake-quantized training keeps
//! the float masters here and writes hardened copies into the graph
//! before each forward (the weight straight-through estimator).
//! [`Gradients`] is what the backward plan produces: per-node gradient
//! buffers whose element order matches the parameters they pair with.

use super::{Graph, NodeId, Op};

/// Which trainable tensor of a node a [`ParamRef`] addresses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    /// Conv2d OIHW / Linear `[in, out]` weights.
    Weight,
    /// Conv2d / Linear per-output-channel bias.
    Bias,
    /// BatchNorm scale γ (frozen-statistics training: μ/σ stay fixed).
    BnGamma,
    /// BatchNorm shift β.
    BnBeta,
    /// PACT learned clip (the paper's α; `beta` in [`Op::PactAct`]).
    PactBeta,
}

/// One trainable parameter tensor of one graph node.
#[derive(Clone, Copy, Debug)]
pub struct ParamRef {
    pub node: NodeId,
    pub kind: ParamKind,
    /// Scalar element count.
    pub len: usize,
}

/// Enumerate every trainable parameter in node order — the deterministic
/// flat layout of [`gather_params`]. `QuantBn` is the already-quantized
/// QD representation and is never trained.
pub fn param_refs(g: &Graph) -> Vec<ParamRef> {
    let mut refs = Vec::new();
    for nd in &g.nodes {
        match &nd.op {
            Op::Conv2d { w, bias, .. } | Op::Linear { w, bias } => {
                refs.push(ParamRef {
                    node: nd.id,
                    kind: ParamKind::Weight,
                    len: w.len(),
                });
                if let Some(b) = bias {
                    refs.push(ParamRef {
                        node: nd.id,
                        kind: ParamKind::Bias,
                        len: b.len(),
                    });
                }
            }
            Op::BatchNorm { bn } => {
                refs.push(ParamRef {
                    node: nd.id,
                    kind: ParamKind::BnGamma,
                    len: bn.gamma.len(),
                });
                refs.push(ParamRef {
                    node: nd.id,
                    kind: ParamKind::BnBeta,
                    len: bn.beta.len(),
                });
            }
            Op::PactAct { .. } => {
                refs.push(ParamRef { node: nd.id, kind: ParamKind::PactBeta, len: 1 });
            }
            _ => {}
        }
    }
    refs
}

/// Total scalar count across `refs`.
pub fn param_len(refs: &[ParamRef]) -> usize {
    refs.iter().map(|r| r.len).sum()
}

/// Read one parameter as f64 (master precision).
pub fn get_param(g: &Graph, r: ParamRef) -> Vec<f64> {
    let nd = &g.nodes[r.node];
    match (&nd.op, r.kind) {
        (Op::Conv2d { w, .. } | Op::Linear { w, .. }, ParamKind::Weight) => {
            w.data().iter().map(|&v| v as f64).collect()
        }
        (
            Op::Conv2d { bias: Some(b), .. } | Op::Linear { bias: Some(b), .. },
            ParamKind::Bias,
        ) => b.clone(),
        (Op::BatchNorm { bn }, ParamKind::BnGamma) => bn.gamma.clone(),
        (Op::BatchNorm { bn }, ParamKind::BnBeta) => bn.beta.clone(),
        (Op::PactAct { beta, .. }, ParamKind::PactBeta) => vec![*beta],
        _ => panic!("param ref mismatch at node {}", r.node),
    }
}

/// Write one parameter from f64 masters (weights narrow to f32).
pub fn set_param(g: &mut Graph, r: ParamRef, vals: &[f64]) {
    assert_eq!(vals.len(), r.len, "param length mismatch at node {}", r.node);
    let nd = &mut g.nodes[r.node];
    match (&mut nd.op, r.kind) {
        (Op::Conv2d { w, .. } | Op::Linear { w, .. }, ParamKind::Weight) => {
            for (wv, &v) in w.data_mut().iter_mut().zip(vals) {
                *wv = v as f32;
            }
        }
        (
            Op::Conv2d { bias: Some(b), .. } | Op::Linear { bias: Some(b), .. },
            ParamKind::Bias,
        ) => b.copy_from_slice(vals),
        (Op::BatchNorm { bn }, ParamKind::BnGamma) => bn.gamma.copy_from_slice(vals),
        (Op::BatchNorm { bn }, ParamKind::BnBeta) => bn.beta.copy_from_slice(vals),
        (Op::PactAct { beta, .. }, ParamKind::PactBeta) => *beta = vals[0],
        _ => panic!("param ref mismatch at node {}", r.node),
    }
}

/// Flatten every parameter named by `refs` into one master vector.
pub fn gather_params(g: &Graph, refs: &[ParamRef]) -> Vec<f64> {
    let mut theta = Vec::with_capacity(param_len(refs));
    for &r in refs {
        theta.extend(get_param(g, r));
    }
    theta
}

/// Write a master vector back into the graph (inverse of
/// [`gather_params`]).
pub fn scatter_params(g: &mut Graph, refs: &[ParamRef], theta: &[f64]) {
    assert_eq!(theta.len(), param_len(refs), "theta length mismatch");
    let mut off = 0;
    for &r in refs {
        set_param(g, r, &theta[off..off + r.len]);
        off += r.len;
    }
}

/// Per-node gradient buffers, element order matching the node's own
/// parameter layout (f32 like the engine; the optimizer accumulates in
/// f64 masters).
#[derive(Clone, Debug, Default)]
pub struct NodeGrad {
    /// dL/dW, same element order as the weight tensor (OIHW / `[in, out]`).
    pub w: Vec<f32>,
    /// dL/db per output channel.
    pub bias: Vec<f32>,
    /// dL/dγ (BatchNorm scale).
    pub gamma: Vec<f32>,
    /// dL/dβ (BatchNorm shift).
    pub beta: Vec<f32>,
    /// dL/dβ for PACT (the learned clip): Σ of dL/dy over elements in the
    /// saturated region x ≥ β (the paper's ∂y/∂α = 1 there, 0 below).
    pub pact_beta: f64,
}

/// All parameter gradients of one backward pass, indexed by [`NodeId`].
#[derive(Clone, Debug)]
pub struct Gradients {
    pub nodes: Vec<NodeGrad>,
}

impl Gradients {
    pub fn zeros(n_nodes: usize) -> Self {
        Gradients { nodes: vec![NodeGrad::default(); n_nodes] }
    }

    /// Gradient of one parameter, flattened to f64 (same element order as
    /// [`get_param`]).
    pub fn param(&self, r: ParamRef) -> Vec<f64> {
        let nd = &self.nodes[r.node];
        match r.kind {
            ParamKind::Weight => nd.w.iter().map(|&v| v as f64).collect(),
            ParamKind::Bias => nd.bias.iter().map(|&v| v as f64).collect(),
            ParamKind::BnGamma => nd.gamma.iter().map(|&v| v as f64).collect(),
            ParamKind::BnBeta => nd.beta.iter().map(|&v| v as f64).collect(),
            ParamKind::PactBeta => vec![nd.pact_beta],
        }
    }

    /// Flatten gradients for `refs` into a vector aligned with
    /// [`gather_params`]'s layout.
    pub fn gather(&self, refs: &[ParamRef]) -> Vec<f64> {
        let mut gtheta = Vec::with_capacity(param_len(refs));
        for &r in refs {
            let gv = self.param(r);
            assert_eq!(
                gv.len(),
                r.len,
                "gradient missing or misshapen at node {}",
                r.node
            );
            gtheta.extend(gv);
        }
        gtheta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bn::BnParams;
    use crate::tensor::Tensor;

    fn conv_bn_pact_fc() -> Graph {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 4, 4] }, &[]);
        let w = Tensor::from_vec(&[2, 1, 3, 3], (0..18).map(|i| i as f32 * 0.1).collect());
        let c = g.push("conv", Op::Conv2d { w, bias: None, stride: 1, pad: 1 }, &[x]);
        let b = g.push("bn", Op::BatchNorm { bn: BnParams::identity(2) }, &[c]);
        let a = g.push("act", Op::PactAct { beta: 4.0, bits: 4 }, &[b]);
        let p = g.push("gap", Op::GlobalAvgPool, &[a]);
        let w2 = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32 * 0.2).collect());
        g.push("fc", Op::Linear { w: w2, bias: Some(vec![0.5, -0.5, 0.25]) }, &[p]);
        g
    }

    #[test]
    fn param_refs_enumerate_in_node_order() {
        let g = conv_bn_pact_fc();
        let refs = param_refs(&g);
        let kinds: Vec<ParamKind> = refs.iter().map(|r| r.kind).collect();
        // conv weight (no bias), bn gamma+beta, pact clip, fc weight+bias.
        assert_eq!(
            kinds,
            vec![
                ParamKind::Weight,
                ParamKind::BnGamma,
                ParamKind::BnBeta,
                ParamKind::PactBeta,
                ParamKind::Weight,
                ParamKind::Bias,
            ]
        );
        assert_eq!(param_len(&refs), 18 + 2 + 2 + 1 + 6 + 3);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut g = conv_bn_pact_fc();
        let refs = param_refs(&g);
        let mut theta = gather_params(&g, &refs);
        for (i, t) in theta.iter_mut().enumerate() {
            *t += 0.125 * (i % 7) as f64;
        }
        scatter_params(&mut g, &refs, &theta);
        let back = gather_params(&g, &refs);
        // Weights round-trip through f32, everything else through f64 —
        // f32 holds these small values exactly.
        for (a, b) in theta.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6, "{a} != {b}");
        }
        // The PACT clip actually moved in the graph.
        match g.nodes[3].op {
            Op::PactAct { beta, .. } => assert!((beta - theta[22]).abs() < 1e-12),
            _ => unreachable!(),
        }
    }

    #[test]
    fn gradients_flatten_like_params() {
        let g = conv_bn_pact_fc();
        let refs = param_refs(&g);
        let mut grads = Gradients::zeros(g.nodes.len());
        grads.nodes[1].w = vec![1.0; 18];
        grads.nodes[2].gamma = vec![2.0; 2];
        grads.nodes[2].beta = vec![3.0; 2];
        grads.nodes[3].pact_beta = 4.0;
        grads.nodes[5].w = vec![5.0; 6];
        grads.nodes[5].bias = vec![6.0; 3];
        let flat = grads.gather(&refs);
        assert_eq!(flat.len(), param_len(&refs));
        assert_eq!(flat[0], 1.0);
        assert_eq!(flat[18], 2.0);
        assert_eq!(flat[20], 3.0);
        assert_eq!(flat[22], 4.0);
        assert_eq!(flat[23], 5.0);
        assert_eq!(flat[29], 6.0);
    }
}
