//! # NEMO-rs: integer-only DNN quantization for deployment
//!
//! A Rust + JAX + Pallas reproduction of Conti, *"Technical Report: NEMO
//! Quantization for Deployment Model"* (2020).
//!
//! The paper defines four DNN representations — FullPrecision,
//! FakeQuantized, QuantizedDeployable, IntegerDeployable — and the
//! transforms between them; the last one runs inference using *only*
//! integers. This crate implements:
//!
//! * the representation pipeline as a **typestate API** ([`network`]):
//!   `Network<FullPrecision> -> Network<FakeQuantized> ->
//!   Network<QuantizedDeployable> -> Network<IntegerDeployable>`, where
//!   only the paper's legal transforms exist between adjacent stages and
//!   illegal pipelines are compile errors;
//! * the transform math behind those transitions over a graph IR
//!   ([`graph`], [`transform`]) and the quantization/requantization math
//!   of paper secs. 2-3 ([`quant`]);
//! * a unified **[`exec::Executor`] backend trait** with three
//!   implementations: the float engine (FP/FQ/QD), the integer-only
//!   engine (ID — the MCU-datapath simulator; both in [`engine`]/
//!   [`exec`]), and a PJRT-backed executor over the AOT-compiled
//!   JAX/Pallas artifacts (feature `pjrt`);
//! * a PJRT runtime ([`runtime`], feature `pjrt`) that loads the
//!   HLO-text artifacts produced by `python/compile/`;
//! * a serving coordinator ([`coordinator`]) with dynamic batching over
//!   a runtime model registry of executors — multi-model serving by
//!   name with hot load / swap / unload and per-model metrics; `serve
//!   --backend native` needs no artifacts at all, `serve --model
//!   a.nemo.json --model b.nemo.json` serves deployment artifacts, and
//!   `--backend pjrt` serves the compiled ones through the same path;
//! * a remote serving subsystem ([`net`]): a framed-TCP wire protocol
//!   carrying packed integer tensors, a socket server over the
//!   coordinator (`nemo serve --listen ADDR`), and a blocking client
//!   library (`nemo client ...`) — remote logits are bit-identical to
//!   in-process inference;
//! * a QAT training driver ([`train`], feature `pjrt`) that runs the
//!   compiled FakeQuantized train step — Python is never on the request
//!   path;
//! * a static soundness verifier ([`analysis`]): an interval abstract
//!   interpreter over the integer graph that proves accumulators fit
//!   the i32 datapath, requants never saturate, and precision stamps
//!   hold — wired into deploy (hard gate), artifact load
//!   (`CheckMode::{Off,Warn,Strict}`) and the `nemo check` CLI verb;
//! * model zoo, synthetic dataset, checkpoint/manifest I/O
//!   ([`model`], [`data`], [`io`]).
//!
//! Feature `pjrt` gates everything that needs the `xla` FFI crate; the
//! default build is pure Rust (native engines + coordinator + pipeline).
//!
//! See DESIGN.md for the paper-to-module map and the typestate pipeline
//! diagram, and EXPERIMENTS.md for the reproduced experiment suite.

// The crate's small unsafe surface (mmap views, packed-storage casts,
// wire-format scratch buffers) is audited: every unsafe operation sits
// in an explicit block with a `// SAFETY:` justification.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod analysis;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod exec;
pub mod graph;
pub mod io;
pub mod model;
pub mod net;
pub mod network;
pub mod quant;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod transform;
pub mod util;
