//! Integration tests over the full representation pipeline
//! (FP -> FQ -> QD -> ID) on multiple architectures, including failure
//! injection. No artifacts required (engine-only). Everything flows
//! through the typed `Network<Stage>` pipeline — the untyped
//! free-function shims were removed after their deprecation window.

use nemo::engine::{FloatEngine, IntegerEngine};
use nemo::graph::{Graph, Op};
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::model::{mlp, residual_net};
use nemo::network::{FakeQuantized, Network};
use nemo::quant::quantize_input;
use nemo::tensor::{Tensor, TensorF};
use nemo::transform::{
    add_input_bias, calibrate, calibrate_percentile, DeployOptions, Deployed,
    TransformError,
};
use nemo::util::rng::Rng;

fn synth_input(rng: &mut Rng, b: usize) -> TensorF {
    Tensor::from_vec(
        &[b, 1, 16, 16],
        (0..b * 256).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    )
}

/// PACT graph -> full deployment record via the typed pipeline.
fn deploy_pact(g: Graph, opts: DeployOptions) -> Result<Deployed, TransformError> {
    Ok(Network::<FakeQuantized>::from_pact_graph(g)?
        .deploy(opts)?
        .integerize()
        .into_deployed())
}

/// FP graph + betas -> full deployment record via the typed pipeline.
fn deploy_fp(
    g: Graph,
    wbits: u32,
    abits: u32,
    betas: &[f64],
    opts: DeployOptions,
) -> Result<Deployed, TransformError> {
    Ok(Network::from_graph(g)?
        .quantize_pact(wbits, abits, betas)?
        .deploy(opts)?
        .integerize()
        .into_deployed())
}

#[test]
fn synthnet_full_pipeline_all_bitwidths() {
    let mut rng = Rng::new(21);
    let net = SynthNet::init(&mut rng);
    let x = synth_input(&mut rng, 8);
    let betas = calibrate_percentile(&net.to_fp_graph(), &[x.clone()], 0.999);
    for bits in [8u32, 4, 2] {
        let mut n2 = net.clone();
        n2.act_betas = betas.clone();
        let dep = deploy_pact(
            n2.to_pact_graph(bits),
            DeployOptions { wbits: bits, abits: bits, ..DeployOptions::default() },
        )
        .unwrap_or_else(|e| panic!("deploy at {bits} bits: {e}"));
        let qx = quantize_input(&x, EPS_IN);
        let id_out = IntegerEngine::new().run(&dep.id, &qx);
        assert_eq!(id_out.shape(), &[8, 10]);
        // QD and ID agree within a few output quanta at 8 bits
        if bits == 8 {
            let x_grid = qx.map(|q| q as f32 / 255.0);
            let qd_out = FloatEngine::new().run(&dep.qd, &x_grid);
            let mut max_diff = 0f64;
            for (a, b) in qd_out.data().iter().zip(id_out.data()) {
                max_diff = max_diff.max((*a as f64 - *b as f64 * dep.eps_out).abs());
            }
            let scale = qd_out.data().iter().fold(0f32, |m, v| m.max(v.abs())) as f64;
            assert!(
                max_diff < 0.05 * scale.max(1.0),
                "QD-ID divergence {max_diff} at scale {scale}"
            );
        }
    }
}

#[test]
fn residual_net_deploys_and_runs_integer_only() {
    let mut rng = Rng::new(22);
    let g = residual_net(&mut rng, EPS_IN);
    let x = synth_input(&mut rng, 4);
    let betas = calibrate(&g, &[x.clone()]);
    let dep = deploy_fp(g, 8, 8, &betas, DeployOptions::default()).unwrap();
    // The Add became AddRequant with one per-extra-branch requant.
    let adds: Vec<_> = dep
        .id
        .nodes
        .iter()
        .filter_map(|n| match &n.op {
            nemo::graph::int::IntOp::AddRequant { rqs } => Some(rqs.len()),
            _ => None,
        })
        .collect();
    assert_eq!(adds, vec![1]);
    let qx = quantize_input(&x, EPS_IN);
    let out = IntegerEngine::new().run(&dep.id, &qx);
    assert_eq!(out.shape(), &[4, 10]);
    // argmax agreement with the QD float path
    let x_grid = qx.map(|q| q as f32 / 255.0);
    let qd = FloatEngine::new().run(&dep.qd, &x_grid);
    assert_eq!(qd.argmax_rows(), out.argmax_rows());
}

#[test]
fn mlp_pipeline_with_input_bias() {
    let mut rng = Rng::new(23);
    let g = mlp(&mut rng, 32, 24, 5, EPS_IN);
    // input with natural offset alpha = -0.25 translated into the fc bias
    let g2 = add_input_bias(&g, -0.25).unwrap();
    let x = Tensor::from_vec(
        &[4, 32],
        (0..128).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );
    let betas = calibrate(&g2, &[x.clone()]);
    let dep = deploy_fp(g2, 8, 8, &betas, DeployOptions::default()).unwrap();
    let qx = quantize_input(&x, EPS_IN);
    let out = IntegerEngine::new().run(&dep.id, &qx);
    assert_eq!(out.shape(), &[4, 5]);
}

#[test]
fn fold_bn_then_deploy_matches_unfolded_argmax() {
    let mut rng = Rng::new(24);
    let net = SynthNet::init(&mut rng);
    let g = net.to_fp_graph();
    let folded = Network::from_graph(g.clone())
        .unwrap()
        .fold_bn(None)
        .unwrap();
    let x = synth_input(&mut rng, 8);
    let betas_a = calibrate(&g, &[x.clone()]);
    let betas_b = calibrate(folded.graph(), &[x.clone()]);
    let dep_a = deploy_fp(g, 8, 8, &betas_a, DeployOptions::default()).unwrap();
    let dep_b = folded
        .quantize_pact(8, 8, &betas_b)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize()
        .into_deployed();
    let qx = quantize_input(&x, EPS_IN);
    let ie = IntegerEngine::new();
    let a = ie.run(&dep_a.id, &qx);
    let b = ie.run(&dep_b.id, &qx);
    assert_eq!(a.argmax_rows(), b.argmax_rows(), "folding changed predictions");
}

#[test]
fn threshold_and_requant_variants_agree() {
    let mut rng = Rng::new(25);
    let net = SynthNet::init(&mut rng);
    let x = synth_input(&mut rng, 8);
    let mut n2 = net.clone();
    n2.act_betas = calibrate_percentile(&net.to_fp_graph(), &[x.clone()], 0.999);
    for bits in [4u32, 2] {
        let mk = |th| {
            deploy_pact(
                n2.to_pact_graph(bits),
                DeployOptions {
                    wbits: bits,
                    abits: bits,
                    use_thresholds: th,
                    ..DeployOptions::default()
                },
            )
            .unwrap()
        };
        let dep_rq = mk(false);
        let dep_th = mk(true);
        let qx = quantize_input(&x, EPS_IN);
        let ie = IntegerEngine::new();
        let a = ie.run(&dep_rq.id, &qx);
        let b = ie.run(&dep_th.id, &qx);
        assert_eq!(a.argmax_rows(), b.argmax_rows(), "bits={bits}");
    }
}

// -- failure injection ------------------------------------------------------

#[test]
fn deploy_refuses_unquantized_network() {
    let mut rng = Rng::new(26);
    let net = SynthNet::init(&mut rng);
    // A FullPrecision graph (plain ReLU) cannot even enter the pipeline
    // at the FakeQuantized stage, let alone deploy.
    match Network::<FakeQuantized>::from_pact_graph(net.to_fp_graph()) {
        Err(TransformError::NeedsFakeQuant(_)) => {}
        other => panic!(
            "expected NeedsFakeQuant, got {:?}",
            other.map(|_| "Network<FakeQuantized>")
        ),
    }
}

#[test]
fn deploy_rejects_overflowing_bitwidths() {
    // 24-bit weights with a wide-fanin conv overflow i32 accumulators;
    // the range analysis must reject rather than deploy silently.
    let mut g = Graph::new(1.0 / 255.0);
    let x = g.push("in", Op::Input { shape: vec![256, 8, 8] }, &[]);
    let w = Tensor::full(&[8, 256, 3, 3], 1.0f32);
    let c = g.push("c", Op::Conv2d { w, bias: None, stride: 1, pad: 1 }, &[x]);
    g.push("a", Op::PactAct { beta: 1.0, bits: 8 }, &[c]);
    match deploy_pact(g, DeployOptions { wbits: 24, ..DeployOptions::default() }) {
        Err(TransformError::RangeOverflow { .. }) => {}
        other => panic!("expected RangeOverflow, got {other:?}"),
    }
}

#[test]
fn calibration_with_empty_batch_list_gives_positive_betas() {
    let mut rng = Rng::new(27);
    let net = SynthNet::init(&mut rng);
    let betas = calibrate(&net.to_fp_graph(), &[]);
    assert!(betas.iter().all(|b| *b > 0.0));
}

#[test]
fn integer_engine_is_deterministic_across_runs() {
    let mut rng = Rng::new(28);
    let net = SynthNet::init(&mut rng);
    let mut n2 = net.clone();
    let x = synth_input(&mut rng, 4);
    n2.act_betas = calibrate(&net.to_fp_graph(), &[x.clone()]);
    let dep = deploy_pact(n2.to_pact_graph(8), DeployOptions::default()).unwrap();
    let qx = quantize_input(&x, EPS_IN);
    let ie = IntegerEngine::new();
    let a = ie.run(&dep.id, &qx);
    let b = ie.run(&dep.id, &qx);
    assert_eq!(a.data(), b.data());
}

#[test]
fn mixed_precision_per_layer_bits() {
    // Memory-driven mixed precision (the paper's ref [4]): each activation
    // carries its own bit width — bits is a per-PactAct-node property, so
    // the pipeline supports heterogeneous configs natively.
    let mut rng = Rng::new(29);
    let net = SynthNet::init(&mut rng);
    let x = synth_input(&mut rng, 4);
    let betas = calibrate(&net.to_fp_graph(), &[x.clone()]);
    let mut g = net.to_fp_graph();
    let mixed_bits = [8u32, 4, 2];
    let mut ai = 0;
    for n in &mut g.nodes {
        if matches!(n.op, Op::ReLU) {
            n.op = Op::PactAct { beta: betas[ai], bits: mixed_bits[ai] };
            ai += 1;
        }
    }
    let dep = deploy_pact(g, DeployOptions::default()).unwrap();
    // each RequantAct clips at its own 2^bits - 1
    let his: Vec<i64> = dep
        .id
        .nodes
        .iter()
        .filter_map(|n| match &n.op {
            nemo::graph::int::IntOp::RequantAct { rq } => Some(rq.hi),
            _ => None,
        })
        .collect();
    assert_eq!(his, vec![255, 15, 3]);
    let qx = quantize_input(&x, EPS_IN);
    let out = IntegerEngine::new().run(&dep.id, &qx);
    assert_eq!(out.shape(), &[4, 10]);
}
