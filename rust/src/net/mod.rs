//! Remote serving subsystem (S8): the framed-TCP wire protocol, the
//! socket server, and the blocking client library.
//!
//! PR 5's [`crate::coordinator`] made multi-model serving a runtime
//! registry, but only in-process. This module puts that registry on the
//! network: [`NetServer`] exposes every [`ServerHandle`] op — `infer`,
//! `infer_deadline`, and the admin surface (`load`/`swap`/`unload`/
//! `list`/`metrics`) — over a length-prefixed, checksummed frame
//! protocol ([`protocol`]), and [`NemoClient`] is the matching blocking
//! client with connect retry, request pipelining, and a `ping`
//! heartbeat.
//!
//! Why a custom integer wire format: IntegerDeployable inference (the
//! paper's deployment representation) is purely integer arithmetic, so
//! replies are bit-reproducible across machines. The protocol leans on
//! that — tensors cross the wire as dtype-tagged `u8`/`i8`/`i32`
//! payloads at packed precision (the artifact format's storage classes),
//! and a loopback round-trip is *bit-identical* to an in-process
//! `ServerHandle::infer`, which the test suite asserts.
//!
//! Layering: the wire layer adds no serving semantics of its own. Swap
//! atomicity w.r.t. in-flight requests, per-model metrics ledgers
//! spanning versions, deadline behaviour — all of that is the
//! coordinator's contract; `NetServer` is a framing + dispatch shim over
//! a `ServerHandle`, so in-process users keep using `ServerHandle`
//! directly (unchanged) and get identical behaviour.
//!
//! ```no_run
//! use nemo::coordinator::Server;
//! use nemo::net::{NemoClient, NetConfig, NetServer};
//!
//! # fn main() -> anyhow::Result<()> {
//! let server = Server::builder()
//!     .model_from_artifact("mnist", "model.nemo.json")
//!     .start()?;
//! let ns = NetServer::bind("127.0.0.1:0", server.handle(), NetConfig::default())?;
//! let addr = ns.local_addr();
//!
//! let mut client = NemoClient::connect(addr)?;
//! client.ping()?;
//! let qx = nemo::tensor::Tensor::from_vec(&[1, 4], vec![0i32; 4]);
//! let _logits = client.infer("mnist", &qx)?; // bit-identical to in-process
//! # Ok(()) }
//! ```

pub mod client;
pub mod protocol;
pub mod server;

pub use client::{ClientConfig, NemoClient};
pub use protocol::{
    pack_lossless, Frame, Opcode, WireCode, WireError, WireMetrics, WireModelInfo,
    WireStat, MAX_PAYLOAD, WIRE_VERSION,
};
pub use server::{NetConfig, NetServer};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Process-wide shutdown flag set by SIGINT/SIGTERM. `nemo serve` polls
/// it to stop accepting, drain in-flight batches via `Server::stop()`,
/// and print the aggregate metrics instead of dying mid-batch.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Install SIGINT + SIGTERM handlers (idempotent) and return a flag that
/// flips to `true` on the first signal. The handler only stores to an
/// atomic — async-signal-safe by construction.
///
/// On non-unix targets this returns the (never-signalled) flag without
/// installing anything; callers still get Ctrl-C via process kill.
pub fn shutdown_flag() -> Arc<ShutdownFlag> {
    #[cfg(unix)]
    install_handlers();
    Arc::new(ShutdownFlag(()))
}

/// Handle onto the process-wide shutdown flag (see [`shutdown_flag`]).
pub struct ShutdownFlag(());

impl ShutdownFlag {
    pub fn is_set(&self) -> bool {
        SHUTDOWN.load(Ordering::SeqCst)
    }

    /// Set the flag programmatically (tests; or a serving loop that
    /// wants to shut itself down through the same path as a signal).
    pub fn trigger(&self) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
}

#[cfg(unix)]
fn install_handlers() {
    // std exposes no signal API and this crate deliberately carries no
    // libc dependency, so declare the two POSIX symbols we need against
    // the libc std already links. The handler parameter is a typed
    // extern "C" fn — not usize — to keep the cast surface minimal.
    extern "C" fn on_signal(_signum: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: signal(2) with a valid signal number and an async-signal-
    // safe extern "C" handler that only stores to an atomic; installing
    // it twice (idempotent Once guard above) would still be sound.
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shutdown_flag_triggers() {
        let f = shutdown_flag();
        // Process-wide flag: don't assert the initial state (another
        // test or a real signal may have set it), only the transition.
        f.trigger();
        assert!(f.is_set());
    }
}
