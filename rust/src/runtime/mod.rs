//! PJRT runtime (S6): loads the AOT-compiled HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//!
//! HLO *text* is the interchange format: jax >= 0.5 serializes
//! HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md and DESIGN.md).
//!
//! All modules are lowered with `return_tuple=True`, so outputs always
//! arrive as one tuple literal that [`Executable::run`] decomposes.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, ensure, Context, Result};

use crate::io::{ArtifactSpec, Manifest};
use crate::tensor::Tensor;

/// Re-exported for compatibility: [`Arg`] now lives in [`crate::exec`],
/// shared by every executor backend (it is no longer PJRT-specific).
pub use crate::exec::Arg;

fn to_literal(a: &Arg) -> Result<xla::Literal> {
    let dims: Vec<i64> = a.shape().iter().map(|d| *d as i64).collect();
    let lit = match a {
        Arg::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
        Arg::I32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
    };
    Ok(lit)
}

fn from_literal(lit: &xla::Literal) -> Result<Arg> {
    let shape = lit.array_shape().context("output literal shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>()?;
            Ok(Arg::F32(Tensor::from_vec(&dims, v)))
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>()?;
            Ok(Arg::I32(Tensor::from_vec(&dims, v)))
        }
        ty => bail!("unsupported output element type {ty:?}"),
    }
}

/// A compiled artifact bound to its argument specification.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the PJRT C API guarantees PJRT_Client and PJRT_LoadedExecutable
// are thread-safe for concurrent Execute calls. The `xla` crate wraps them
// in `Rc` + raw pointers (hence !Send/!Sync), but this crate never clones
// the inner Rc across threads: `Executable` is shared via `Arc`, the Rc
// refcount is only touched at construction (runtime thread) and at final
// drop (after worker threads have joined — the Runtime cache outlives all
// workers). Concurrent `run()` only calls Execute, which is thread-safe.
unsafe impl Send for Executable {}
// SAFETY: see the Send justification above — concurrent shared access
// only reaches Execute, which the PJRT C API declares thread-safe.
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with positional arguments; validates shapes/dtypes against
    /// the manifest before crossing the FFI boundary.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<Arg>> {
        ensure!(
            args.len() == self.spec.args.len(),
            "{}: got {} args, manifest says {}",
            self.spec.name,
            args.len(),
            self.spec.args.len()
        );
        for (a, s) in args.iter().zip(&self.spec.args) {
            ensure!(
                a.shape() == &s.shape[..],
                "{}: arg '{}' shape {:?} != manifest {:?}",
                self.spec.name,
                s.name,
                a.shape(),
                s.shape
            );
            let ok = matches!(
                (a, s.dtype.as_str()),
                (Arg::F32(_), "float32") | (Arg::I32(_), "int32")
            );
            ensure!(ok, "{}: arg '{}' dtype mismatch ({})", self.spec.name, s.name, s.dtype);
        }
        let lits: Vec<xla::Literal> =
            args.iter().map(to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(
            outs.len() == self.spec.n_outputs,
            "{}: got {} outputs, manifest says {}",
            self.spec.name,
            outs.len(),
            self.spec.n_outputs
        );
        outs.iter().map(from_literal).collect()
    }
}

/// PJRT CPU runtime with a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

// SAFETY: the xla PJRT CPU client is internally synchronized; executables
// are immutable after compilation. We gate shared access through Arc anyway.
unsafe impl Send for Runtime {}
// SAFETY: same argument — the client synchronizes internally and the
// executable cache sits behind a Mutex.
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.find(name)?.clone();
        let path = spec.file.to_str().context("artifact path utf8")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let arc = std::sync::Arc::new(Executable { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), arc.clone());
        Ok(arc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::artifacts_dir;
    use crate::tensor::{TensorF, TensorI};

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    #[test]
    fn kernel_qgemm_roundtrip() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("kernel_qgemm_256").unwrap();
        let a = TensorI::full(&[256, 256], 2);
        let b = TensorI::full(&[256, 256], 3);
        let out = exe.run(&[a.into(), b.into()]).unwrap();
        let y = out[0].as_i32().unwrap();
        assert_eq!(y.shape(), &[256, 256]);
        assert!(y.data().iter().all(|v| *v == 2 * 3 * 256));
    }

    #[test]
    fn arg_validation_catches_mistakes() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("kernel_qgemm_256").unwrap();
        // wrong count
        assert!(exe.run(&[]).is_err());
        // wrong shape
        let a = TensorI::full(&[4, 4], 1);
        let b = TensorI::full(&[256, 256], 1);
        assert!(exe.run(&[a.into(), b.clone().into()]).is_err());
        // wrong dtype
        let af = TensorF::full(&[256, 256], 1.0);
        assert!(exe.run(&[af.into(), b.into()]).is_err());
    }
}
