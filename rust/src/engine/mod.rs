//! Graph executors (S5 in DESIGN.md).
//!
//! * [`FloatEngine`] runs FP / FQ / QD graphs on f32 tensors.
//! * [`IntegerEngine`] runs IntegerDeployable graphs using i32 integer
//!   images with i64 widening — no floating point on the value path. It
//!   is the simulator standing in for the paper's MCU integer datapath
//!   (DESIGN.md §Hardware-Adaptation).
//!
//! These are the raw single-call engines; for batched serving and
//! backend-interchangeable execution they are wrapped by the
//! [`crate::exec::Executor`] implementations.

pub mod float;
pub mod integer;

pub use float::FloatEngine;
pub use integer::IntegerEngine;
