//! Typestate pipeline integration tests: the legal chain FP -> FQ -> QD
//! -> ID must agree *bit-exactly* with the legacy free-function path
//! (the deprecated shims kept in `transform::`), stage metadata must
//! accumulate correctly, and the IntegerDeployable stage must plug into
//! the unified `Executor` backend. Illegal transitions are compile
//! errors — proven by the `compile_fail` doc-tests on `nemo::network`.
#![allow(deprecated)] // half of these tests pin the legacy shims

use nemo::engine::{FloatEngine, IntegerEngine};
use nemo::exec::{ExecInput, Executor};
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::model::{mlp, residual_net};
use nemo::network::{FakeQuantized, Network};
use nemo::quant::quantize_input;
use nemo::tensor::{Tensor, TensorF};
use nemo::transform::{
    calibrate, deploy, fold_bn, quantize_pact, DeployOptions, TransformError,
};
use nemo::util::rng::Rng;

fn synth_input(rng: &mut Rng, b: usize) -> TensorF {
    Tensor::from_vec(
        &[b, 1, 16, 16],
        (0..b * 256).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    )
}

#[test]
fn typed_chain_is_bit_exact_with_free_function_path_mlp() {
    let mut rng = Rng::new(51);
    let g = mlp(&mut rng, 32, 24, 10, EPS_IN);
    let x = Tensor::from_vec(
        &[4, 32],
        (0..128).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );

    // Legacy path: loose free functions over untyped Graphs.
    let betas_old = calibrate(&g, &[x.clone()]);
    let fq_old = quantize_pact(&g, 8, 8, &betas_old);
    let dep_old = deploy(&fq_old, DeployOptions::default()).unwrap();

    // Typed path.
    let fp = Network::from_graph(g.clone()).unwrap();
    let betas_new = fp.calibrate(&[x.clone()]);
    assert_eq!(betas_old, betas_new);
    let fq = fp.quantize_pact(8, 8, &betas_new).unwrap();

    // FQ graphs agree bit-exactly.
    let fe = FloatEngine::new();
    assert_eq!(fe.run(&fq_old, &x).data(), fq.run(&x).data());

    let qd = fq.deploy(DeployOptions::default()).unwrap();
    let id = qd.integerize();

    // QD float outputs agree bit-exactly.
    assert_eq!(
        fe.run(&dep_old.qd, &x).data(),
        fe.run(&id.deployed().qd, &x).data()
    );
    // ID integer outputs agree bit-exactly.
    let qx = quantize_input(&x, EPS_IN);
    let ie = IntegerEngine::new();
    let old_out = ie.run(&dep_old.id, &qx);
    let new_out = id.run(&qx);
    assert_eq!(old_out.data(), new_out.data());
    assert_eq!(dep_old.eps_out.to_bits(), id.eps_out().to_bits());
}

#[test]
fn typed_chain_is_bit_exact_with_free_function_path_synthnet() {
    let mut rng = Rng::new(52);
    let net = SynthNet::init(&mut rng);
    let x = synth_input(&mut rng, 8);
    let qx = quantize_input(&x, EPS_IN);

    // Legacy path (what main.rs used to do).
    let dep_old = deploy(&net.to_pact_graph(8), DeployOptions::default()).unwrap();
    let old_out = IntegerEngine::new().run(&dep_old.id, &qx);

    // Typed path via SynthNet::to_network.
    let nid = net
        .to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize();
    assert_eq!(old_out.data(), nid.run(&qx).data());
    assert_eq!(dep_old.eps_out.to_bits(), nid.eps_out().to_bits());
    // Per-layer quantization tables agree.
    assert_eq!(dep_old.layers.len(), nid.layers().len());
    for (a, b) in dep_old.layers.iter().zip(nid.layers()) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.m, b.m);
        assert_eq!(a.d, b.d);
        assert_eq!(a.eps_w.to_bits(), b.eps_w.to_bits());
    }
}

#[test]
fn typed_fold_bn_matches_free_function_and_cannot_repeat() {
    let mut rng = Rng::new(53);
    let net = SynthNet::init(&mut rng);
    let g = net.to_fp_graph();
    let x = synth_input(&mut rng, 4);

    let folded_old = fold_bn(&g, None).unwrap();
    let folded_new = Network::from_graph(g).unwrap().fold_bn(None).unwrap();
    let fe = FloatEngine::new();
    assert_eq!(
        fe.run(&folded_old, &x).data(),
        folded_new.run(&x).data(),
        "typed fold_bn must be the same transform"
    );
    // The legacy shim silently corrupts weights when applied twice; the
    // typed pipeline refuses.
    assert!(matches!(
        folded_new.fold_bn(None),
        Err(TransformError::AlreadyFolded)
    ));
}

#[test]
fn residual_net_flows_through_typed_pipeline() {
    let mut rng = Rng::new(54);
    let g = residual_net(&mut rng, EPS_IN);
    let x = synth_input(&mut rng, 4);
    let fp = Network::from_graph(g).unwrap();
    let betas = fp.calibrate(&[x.clone()]);
    let id = fp
        .quantize_pact(8, 8, &betas)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize();
    let out = id.run(&quantize_input(&x, EPS_IN));
    assert_eq!(out.shape(), &[4, 10]);
}

#[test]
fn from_pact_graph_rejects_full_precision_graphs() {
    let mut rng = Rng::new(55);
    let net = SynthNet::init(&mut rng);
    assert!(matches!(
        Network::<FakeQuantized>::from_pact_graph(net.to_fp_graph()),
        Err(TransformError::NeedsFakeQuant(_))
    ));
}

#[test]
fn native_executor_matches_direct_engine_run() {
    let mut rng = Rng::new(56);
    let net = SynthNet::init(&mut rng);
    let nid = net
        .to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize();
    let exec = nid.to_executor(8).unwrap();
    assert_eq!(exec.input_shape(), &[1, 16, 16]);

    let x = synth_input(&mut rng, 4);
    let qx = quantize_input(&x, EPS_IN);
    let out = exec.run_batch(&ExecInput::i32(qx.clone())).unwrap();
    assert_eq!(
        out.int_logits().unwrap().data(),
        nid.run(&qx).data(),
        "Executor and direct engine must agree bit-exactly"
    );
}
