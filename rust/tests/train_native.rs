//! End-to-end native training: `train::native` must learn without any
//! PJRT runtime, resume from its own checkpoints, and its trained model
//! must deploy (FQ -> QD -> ID) and serve bit-identically across a
//! checkpoint save/load round-trip.

use nemo::coordinator::{Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::io::Checkpoint;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::quantize_input;
use nemo::train::native::{train_fp, train_fq, OptState};
use nemo::train::{eval_float, eval_integer, TrainConfig};
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn cfg(steps: usize, lr: f64, seed: u64) -> TrainConfig {
    TrainConfig {
        steps,
        lr,
        lr_decay: true,
        seed,
        log_every: 0,
        batch: 32,
        ..TrainConfig::default()
    }
}

fn deploy(net: &SynthNet) -> Network<IntegerDeployable> {
    net.to_network(8).unwrap().deploy(DeployOptions::default()).unwrap().integerize()
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("nemo_train_native_{tag}_{}.json", std::process::id()))
}

#[test]
fn native_training_is_deterministic() {
    let run = || {
        let mut rng = Rng::new(43);
        let mut net = SynthNet::init(&mut rng);
        let mut data = SynthDigits::new(43);
        let mut opt = OptState::default();
        let rep = train_fp(&mut net, &mut data, &cfg(12, 0.1, 43), &mut opt).unwrap();
        (rep.losses, net.fc_w.data().to_vec())
    };
    let (l1, w1) = run();
    let (l2, w2) = run();
    assert_eq!(l1, l2, "loss curves diverge across identical runs");
    assert_eq!(w1, w2, "weights diverge across identical runs");
}

#[test]
fn checkpoint_resume_restores_model_and_optimizer() {
    let mut rng = Rng::new(17);
    let mut net = SynthNet::init(&mut rng);
    let mut data = SynthDigits::new(17);
    let mut opt = OptState::default();

    // a monolithic 20-step run must be closely reproduced by 10 steps,
    // save/load (model + opt.* keys), 10 more over the same data stream.
    // lr_decay is off so both see the same LR sequence.
    let mut c = cfg(10, 0.1, 17);
    c.lr_decay = false;
    let mut cf = cfg(20, 0.1, 17);
    cf.lr_decay = false;
    let mut net_ref = net.clone();
    let mut data_ref = SynthDigits::new(17);
    let mut opt_ref = OptState::default();
    train_fp(&mut net_ref, &mut data_ref, &cf, &mut opt_ref).unwrap();

    train_fp(&mut net, &mut data, &c, &mut opt).unwrap();
    let path = tmp_path("resume");
    let mut ck = net.to_checkpoint();
    opt.save(&mut ck);
    ck.save(&path).unwrap();

    let ck2 = Checkpoint::load(&path).unwrap();
    let mut net2 = SynthNet::from_checkpoint(&ck2).unwrap();
    let mut opt2 = OptState::load(&ck2);
    assert_eq!(opt2.step, 10);
    assert_eq!(opt2.v, opt.v, "momentum buffer must survive the round-trip");
    train_fp(&mut net2, &mut data, &c, &mut opt2).unwrap();
    assert_eq!(opt2.step, 20);

    // Weights cross the checkpoint boundary through the graph's f32
    // storage, so the resumed leg restarts from f32-rounded masters —
    // close to, but not bit-equal with, the monolithic f64 masters.
    let max_diff = net2
        .fc_w
        .data()
        .iter()
        .zip(net_ref.fc_w.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "resumed run diverged: max |dw| = {max_diff:e}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn native_train_deploy_serve_bit_identical_roundtrip() {
    let mut rng = Rng::new(7);
    let mut net = SynthNet::init(&mut rng);
    let mut data = SynthDigits::new(7);
    let mut opt = OptState::default();

    // FP leg must learn
    let rep = train_fp(&mut net, &mut data, &cfg(80, 0.1, 7), &mut opt).unwrap();
    let (head, tail) = rep.head_tail(10);
    assert!(tail < head - 0.1, "native FP training did not learn: {head:.3} -> {tail:.3}");

    // calibrate clips from the trained net, then a short QAT leg
    let fp = Network::from_graph(net.to_fp_graph()).unwrap();
    let (cal_x, _) = data.batch(64);
    net.act_betas = fp.calibrate_percentile(&[cal_x], 0.995);
    let rep2 = train_fq(&mut net, &mut data, 8, 8, &cfg(30, 0.02, 7), &mut opt).unwrap();
    assert!(rep2.final_loss().is_finite());

    // the trained model beats chance on held-out data, in float and int
    let (ex, el) = SynthDigits::eval_set(7, 256);
    let acc = eval_float(&net.to_fp_graph(), &ex, &el);
    assert!(acc > 0.2, "trained FP accuracy {acc:.3} is chance-level");
    let nid = deploy(&net);
    let id_acc = eval_integer(nid.int_graph(), &ex, &el, EPS_IN);
    assert!(id_acc > 0.2, "deployed ID accuracy {id_acc:.3} is chance-level");

    // checkpoint round-trip, deploy both, serve both: bit-identical
    let path = tmp_path("deploy");
    net.to_checkpoint().save(&path).unwrap();
    let net2 = SynthNet::from_checkpoint(&Checkpoint::load(&path).unwrap()).unwrap();
    let nid2 = deploy(&net2);

    let exec1 = nid.to_shared_executor(8).unwrap();
    let exec2 = nid2.to_shared_executor(8).unwrap();
    let server = Server::builder()
        .default_config(ServerConfig::default())
        .model("orig", exec1)
        .model("reloaded", exec2)
        .start()
        .unwrap();
    let h = server.handle();
    let mut data = SynthDigits::new(99);
    for _ in 0..16 {
        let (x, _) = data.batch(1);
        let qx = quantize_input(&x, EPS_IN);
        let a = h.infer("orig", qx.clone()).unwrap();
        let b = h.infer("reloaded", qx.clone()).unwrap();
        assert_eq!(a.data(), b.data(), "served logits differ across save/load");
        let local = nid.run(&qx);
        assert_eq!(a.data(), local.data(), "serving changed the local result");
    }
    server.stop();
    let _ = std::fs::remove_file(&path);
}
