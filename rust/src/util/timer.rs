//! Tiny timing helpers shared by the bench harness and the coordinator.

use std::time::Instant;

/// Measure `f` `iters` times and return per-iteration seconds.
pub fn time_iters<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    start.elapsed().as_secs_f64() / iters as f64
}

/// Run `f` repeatedly until `min_time` seconds elapse (after `warmup`
/// iterations), returning (mean_secs, iters). criterion-lite.
pub fn bench<F: FnMut()>(warmup: usize, min_time: f64, mut f: F) -> (f64, usize) {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut iters = 0usize;
    while start.elapsed().as_secs_f64() < min_time {
        f();
        iters += 1;
    }
    (start.elapsed().as_secs_f64() / iters.max(1) as f64, iters)
}

/// Pretty time formatting for bench output.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn bench_runs() {
        let mut x = 0u64;
        let (t, iters) = super::bench(1, 0.01, || {
            x = x.wrapping_add(1);
        });
        assert!(t > 0.0);
        assert!(iters > 0);
    }

    #[test]
    fn fmt() {
        assert!(super::fmt_time(2e-9).contains("ns"));
        assert!(super::fmt_time(2e-6).contains("µs"));
        assert!(super::fmt_time(2e-3).contains("ms"));
        assert!(super::fmt_time(2.0).contains(" s"));
    }
}
