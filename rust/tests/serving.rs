//! Coordinator integration tests: correctness under concurrency, batching
//! behaviour, failure handling. These run over the *native* integer
//! executor — no artifacts, no PJRT — because the coordinator is backend
//! agnostic; a PJRT round-trip rides along behind the `pjrt` feature.
//! Registry lifecycle (swap/unload/load) tests live in tests/registry.rs.

use std::sync::Arc;
use std::time::Duration;

use nemo::coordinator::{RegistryError, Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::quantize_input;
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn deployed_net(seed: u64) -> Network<IntegerDeployable> {
    let mut rng = Rng::new(seed);
    let net = SynthNet::init(&mut rng);
    net.to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize()
}

fn start_native_server(nid: &Network<IntegerDeployable>, cfg: ServerConfig) -> Server {
    let exec = nid.to_shared_executor(cfg.max_batch.max(1)).unwrap();
    Server::builder()
        .default_config(cfg)
        .model("synthnet", exec)
        .start()
        .unwrap()
}

#[test]
fn served_results_match_local_engine_exactly() {
    let nid = deployed_net(31);
    let server = start_native_server(&nid, ServerConfig::default());
    let h = server.handle();
    let mut data = SynthDigits::new(32);
    for _ in 0..32 {
        let (x, _) = data.batch(1);
        let qx = quantize_input(&x, EPS_IN);
        let served = h.infer("synthnet", qx.clone()).unwrap();
        let local = nid.run(&qx);
        assert_eq!(served.data(), local.data(), "serving must not change results");
    }
    let m = server.stop();
    assert_eq!(m.completed, 32);
    assert_eq!(m.failed, 0);
}

#[test]
fn concurrent_clients_all_get_correct_answers() {
    let nid = Arc::new(deployed_net(33));
    let server = start_native_server(
        &nid,
        ServerConfig {
            max_batch: 16,
            batch_timeout: Duration::from_micros(400),
            n_workers: 2,
        },
    );
    let mut joins = Vec::new();
    for c in 0..8u64 {
        let h = server.handle();
        let nid = nid.clone();
        joins.push(std::thread::spawn(move || {
            let mut data = SynthDigits::new(100 + c);
            for _ in 0..24 {
                let (x, _) = data.batch(1);
                let qx = quantize_input(&x, EPS_IN);
                let served = h.infer("synthnet", qx.clone()).unwrap();
                let local = nid.run(&qx);
                assert_eq!(served.data(), local.data());
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = server.stop();
    assert_eq!(m.completed, 8 * 24);
    // with 8 concurrent clients the batcher should coalesce
    assert!(
        m.batch_sizes.mean() > 1.0,
        "batcher never batched: mean {}",
        m.batch_sizes.mean()
    );
}

#[test]
fn unknown_model_is_rejected_not_hung() {
    let nid = deployed_net(34);
    let server = start_native_server(&nid, ServerConfig::default());
    let h = server.handle();
    let qx = nemo::tensor::TensorI::zeros(&[1, 1, 16, 16]);
    let err = h.infer("nonexistent", qx).unwrap_err();
    assert!(err.to_string().contains("unknown model"));
    // the rejection is typed, not a string-only anyhow error
    assert!(matches!(
        err.downcast_ref::<RegistryError>(),
        Some(RegistryError::UnknownModel(n)) if n == "nonexistent"
    ));
    server.stop();
}

#[test]
fn duplicate_model_names_are_a_typed_build_error() {
    // Regression: Server::start(Vec<ModelVariant>) silently last-wins on
    // duplicate names via HashMap insert. The builder must refuse.
    let nid = deployed_net(42);
    let err = Server::builder()
        .model("synthnet", nid.to_shared_executor(4).unwrap())
        .model("synthnet", nid.to_shared_executor(4).unwrap())
        .start()
        .unwrap_err();
    assert!(matches!(
        err.downcast_ref::<RegistryError>(),
        Some(RegistryError::DuplicateName(n)) if n == "synthnet"
    ));
}

#[test]
fn wrong_shaped_request_gets_an_error_not_garbage() {
    // Regression: dispatch() used to only debug_assert the per-sample
    // shape — in release builds a wrong-shaped infer() silently padded or
    // truncated the gathered batch. It must reply with an Err.
    let nid = deployed_net(35);
    let server = start_native_server(&nid, ServerConfig::default());
    let h = server.handle();
    // wrong spatial shape
    let bad = nemo::tensor::TensorI::zeros(&[1, 1, 8, 8]);
    let err = h.infer("synthnet", bad).unwrap_err();
    assert!(
        err.to_string().contains("does not match"),
        "unexpected error: {err}"
    );
    // multi-sample request (must be [1, ...])
    let multi = nemo::tensor::TensorI::zeros(&[2, 1, 16, 16]);
    assert!(h.infer("synthnet", multi).is_err());
    // a good request still works afterwards
    let good = nemo::tensor::TensorI::zeros(&[1, 1, 16, 16]);
    assert!(h.infer("synthnet", good).is_ok());
    let m = server.stop();
    // rejected requests are visible in the metrics, not silently dropped
    assert_eq!(m.completed, 1);
    assert_eq!(m.failed, 2);
}

#[test]
fn batch_chunking_respects_executor_max_batch() {
    // Executor allows at most 4 per run; push 11 concurrent requests and
    // make sure every one is answered correctly.
    let nid = Arc::new(deployed_net(36));
    let server = start_native_server(
        &nid,
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(20),
            n_workers: 1,
        },
    );
    let mut handles = Vec::new();
    let mut data = SynthDigits::new(37);
    for _ in 0..11 {
        let (x, _) = data.batch(1);
        let qx = quantize_input(&x, EPS_IN);
        let h = server.handle();
        let qx2 = qx.clone();
        handles.push((qx, std::thread::spawn(move || h.infer("synthnet", qx2).unwrap())));
    }
    for (qx, j) in handles {
        let served = j.join().unwrap();
        let local = nid.run(&qx);
        assert_eq!(served.data(), local.data());
    }
    let m = server.stop();
    assert_eq!(m.completed, 11);
    assert_eq!(m.failed, 0);
}

// -- f32 logits protocol (integer-request backend contract) ----------------

/// Stub backend returning f32 logits: integer-valued (some XLA lowerings
/// emit integer math as f32) or genuinely fractional.
struct FloatLogitsStub {
    value: f32,
}

impl nemo::exec::Executor for FloatLogitsStub {
    fn name(&self) -> &str {
        "stub-float"
    }

    fn input_shape(&self) -> &[usize] {
        &[2]
    }

    fn max_batch(&self) -> usize {
        8
    }

    fn run_batch(
        &self,
        input: &nemo::exec::ExecInput,
    ) -> anyhow::Result<nemo::exec::ExecOutput> {
        let n = input.batch_size();
        let t = nemo::tensor::TensorF::from_vec(&[n, 1], vec![self.value; n]);
        Ok(nemo::exec::ExecOutput { logits: nemo::exec::Arg::F32(t) })
    }
}

#[test]
fn near_integer_f32_logits_are_rounded_not_truncated() {
    // 2.9999997 under the old `v as i32` truncation served 2; the
    // contract says round-to-nearest.
    let server = Server::builder()
        .model("stub", Arc::new(FloatLogitsStub { value: 2.999_999_7 }))
        .start()
        .unwrap();
    let h = server.handle();
    let out = h.infer("stub", nemo::tensor::TensorI::zeros(&[1, 2])).unwrap();
    assert_eq!(out.data(), &[3]);
    let m = server.stop();
    assert_eq!(m.completed, 1);
    assert_eq!(m.failed, 0);
}

#[test]
fn fractional_f32_logits_fail_loudly() {
    let server = Server::builder()
        .model("stub", Arc::new(FloatLogitsStub { value: 1.5 }))
        .start()
        .unwrap();
    let h = server.handle();
    let err = h
        .infer("stub", nemo::tensor::TensorI::zeros(&[1, 2]))
        .unwrap_err();
    assert!(
        err.to_string().contains("integer logits protocol"),
        "unexpected error: {err}"
    );
    let m = server.stop();
    assert_eq!(m.completed, 0);
    assert_eq!(m.failed, 1);
}

// -- PJRT parity (requires artifacts + the `pjrt` feature) -----------------

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use nemo::exec::PjrtExecutor;
    use nemo::io::artifacts_dir;
    use nemo::model::artifact_args::synthnet_id_args;
    use nemo::runtime::Runtime;

    /// The same requests served by the native engine and the compiled
    /// PJRT executables must produce bit-identical integer logits.
    #[test]
    fn native_and_pjrt_backends_agree_bit_exactly() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let rt = Runtime::new(dir).unwrap();
        let nid = deployed_net(38);
        let base_args = synthnet_id_args(nid.deployed()).unwrap();
        let pjrt_exec = PjrtExecutor::load(&rt, "id_fwd", base_args).unwrap();
        let pjrt_server = Server::builder()
            .model("synthnet", Arc::new(pjrt_exec))
            .start()
            .unwrap();
        let native_server = start_native_server(&nid, ServerConfig::default());

        let hp = pjrt_server.handle();
        let hn = native_server.handle();
        let mut data = SynthDigits::new(39);
        for _ in 0..16 {
            let (x, _) = data.batch(1);
            let qx = quantize_input(&x, EPS_IN);
            let a = hp.infer("synthnet", qx.clone()).unwrap();
            let b = hn.infer("synthnet", qx).unwrap();
            assert_eq!(a.data(), b.data(), "backends must be interchangeable");
        }
        pjrt_server.stop();
        native_server.stop();
    }

    /// 3 requests -> the b=4 compiled variant with 1 padded sample; the
    /// executor's pad-and-slice logic must return exactly the 3 real
    /// rows, identical to local execution.
    #[test]
    fn batch_variant_selection_pads_correctly() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let rt = Runtime::new(dir).unwrap();
        let nid = Arc::new(deployed_net(40));
        let base_args = synthnet_id_args(nid.deployed()).unwrap();
        let exec = PjrtExecutor::load(&rt, "id_fwd", base_args).unwrap();
        let server = Server::builder()
            .default_config(ServerConfig {
                max_batch: 4,
                batch_timeout: Duration::from_millis(20),
                n_workers: 1,
            })
            .model("synthnet", Arc::new(exec))
            .start()
            .unwrap();
        let mut data = SynthDigits::new(41);
        let mut handles = Vec::new();
        for _ in 0..3 {
            let (x, _) = data.batch(1);
            let qx = quantize_input(&x, EPS_IN);
            let h = server.handle();
            let qx2 = qx.clone();
            handles
                .push((qx, std::thread::spawn(move || h.infer("synthnet", qx2).unwrap())));
        }
        for (qx, j) in handles {
            let served = j.join().unwrap();
            let local = nid.run(&qx);
            assert_eq!(served.data(), local.data());
        }
        let m = server.stop();
        assert_eq!(m.completed, 3);
        // (m.padded is usually 1 here, but batching under timing jitter
        // may split the requests — correctness of the pad/slice path is
        // what the per-sample equality above pins down.)
    }
}
