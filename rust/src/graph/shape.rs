//! Static shape inference for both graph flavours (the first stage of
//! plan compilation, DESIGN.md §Plan-compilation).
//!
//! Given a graph and a batch size, compute the full output shape
//! (including the batch dimension) of every node *before* executing
//! anything. The planner uses these shapes for liveness analysis and
//! arena sizing; executors use them to validate inputs once at compile
//! time instead of asserting per request.
//!
//! Only the batch dimension depends on the batch size — every other
//! extent is a function of the graph alone — so plans cache the
//! per-sample shapes and re-derive per-batch layouts cheaply.

use crate::graph::int::{IntGraph, IntOp};
use crate::graph::{Graph, NodeId, Op};
use crate::quant::Precision;

#[derive(Debug, thiserror::Error)]
pub enum ShapeError {
    #[error("node {id} ({name}): {msg}")]
    Node { id: NodeId, name: String, msg: String },
    #[error("graph has no nodes")]
    Empty,
    #[error("batch size must be >= 1")]
    EmptyBatch,
}

fn node_err(id: NodeId, name: &str, msg: impl Into<String>) -> ShapeError {
    ShapeError::Node { id, name: name.to_string(), msg: msg.into() }
}

/// `shapes[inputs[i]]` with an explicit lifetime (the inference walk
/// reads earlier entries of the table it is still building).
fn nth<'s>(shapes: &'s [Vec<usize>], inputs: &[NodeId], i: usize) -> &'s [usize] {
    &shapes[inputs[i]]
}

/// Output extents of a conv window: (H + 2*pad - K) / stride + 1,
/// rejecting windows larger than the padded input.
fn conv_extent(
    id: NodeId,
    name: &str,
    dim: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<usize, ShapeError> {
    if stride == 0 {
        return Err(node_err(id, name, "stride must be >= 1"));
    }
    if dim + 2 * pad < k {
        return Err(node_err(
            id,
            name,
            format!("kernel {k} larger than padded input {dim}+2*{pad}"),
        ));
    }
    Ok((dim + 2 * pad - k) / stride + 1)
}

fn pool_extents(
    id: NodeId,
    name: &str,
    shape: &[usize],
    k: usize,
) -> Result<Vec<usize>, ShapeError> {
    if shape.len() != 4 {
        return Err(node_err(id, name, format!("pool on rank-{} tensor", shape.len())));
    }
    let (h, w) = (shape[2], shape[3]);
    if k == 0 || h % k != 0 || w % k != 0 {
        return Err(node_err(
            id,
            name,
            format!("pool window {k} does not divide spatial dims {h}x{w}"),
        ));
    }
    Ok(vec![shape[0], shape[1], h / k, w / k])
}

fn channels_of(shape: &[usize]) -> Option<usize> {
    match shape.len() {
        4 | 2 => Some(shape[1]),
        _ => None,
    }
}

fn want_channels(
    id: NodeId,
    name: &str,
    shape: &[usize],
    c: usize,
    what: &str,
) -> Result<(), ShapeError> {
    match channels_of(shape) {
        Some(got) if got == c => Ok(()),
        Some(got) => Err(node_err(
            id,
            name,
            format!("{what} has {c} channels but input has {got}"),
        )),
        None => Err(node_err(
            id,
            name,
            format!("per-channel op on rank-{} tensor", shape.len()),
        )),
    }
}

/// Infer the full shape (batch dim included) of every node of a float
/// [`Graph`] for batch size `batch`.
pub fn infer_float(g: &Graph, batch: usize) -> Result<Vec<Vec<usize>>, ShapeError> {
    if g.nodes.is_empty() {
        return Err(ShapeError::Empty);
    }
    if batch == 0 {
        return Err(ShapeError::EmptyBatch);
    }
    let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        if !matches!(n.op, Op::Input { .. }) && n.inputs.is_empty() {
            return Err(node_err(n.id, &n.name, "non-Input node has no inputs"));
        }
        let shape = match &n.op {
            Op::Input { shape } => {
                let mut s = vec![batch];
                s.extend_from_slice(shape);
                s
            }
            Op::Conv2d { w, stride, pad, .. } => {
                let x = nth(&shapes, &n.inputs, 0);
                if x.len() != 4 {
                    return Err(node_err(n.id, &n.name, "conv on non-NCHW input"));
                }
                let (co, ci, kh, kw) =
                    (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
                if x[1] != ci {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!("weights expect {ci} input channels, got {}", x[1]),
                    ));
                }
                let oh = conv_extent(n.id, &n.name, x[2], kh, *stride, *pad)?;
                let ow = conv_extent(n.id, &n.name, x[3], kw, *stride, *pad)?;
                vec![x[0], co, oh, ow]
            }
            Op::Linear { w, .. } => {
                let x = nth(&shapes, &n.inputs, 0);
                let (fi, fo) = (w.shape()[0], w.shape()[1]);
                if x.len() != 2 || x[1] != fi {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!("linear expects [B, {fi}], got {x:?}"),
                    ));
                }
                vec![x[0], fo]
            }
            Op::BatchNorm { bn } => {
                let x = nth(&shapes, &n.inputs, 0);
                want_channels(n.id, &n.name, x, bn.channels(), "BatchNorm")?;
                x.to_vec()
            }
            Op::QuantBn { kappa_hat, .. } => {
                let x = nth(&shapes, &n.inputs, 0);
                want_channels(n.id, &n.name, x, kappa_hat.len(), "QuantBn")?;
                x.to_vec()
            }
            Op::ReLU | Op::PactAct { .. } => nth(&shapes, &n.inputs, 0).to_vec(),
            Op::MaxPool { k } | Op::AvgPool { k } => {
                pool_extents(n.id, &n.name, nth(&shapes, &n.inputs, 0), *k)?
            }
            Op::GlobalAvgPool => {
                let x = nth(&shapes, &n.inputs, 0);
                if x.len() != 4 {
                    return Err(node_err(n.id, &n.name, "global pool on non-NCHW input"));
                }
                vec![x[0], x[1]]
            }
            Op::Flatten => {
                let x = nth(&shapes, &n.inputs, 0);
                vec![x[0], x[1..].iter().product()]
            }
            Op::Add => {
                let first = nth(&shapes, &n.inputs, 0).to_vec();
                for (bi, &i) in n.inputs.iter().enumerate().skip(1) {
                    if shapes[i] != first {
                        return Err(node_err(
                            n.id,
                            &n.name,
                            format!(
                                "Add branch {bi} shape {:?} != branch 0 shape {first:?}",
                                shapes[i]
                            ),
                        ));
                    }
                }
                first
            }
        };
        shapes.push(shape);
    }
    Ok(shapes)
}

/// Infer the full shape (batch dim included) of every node of an
/// [`IntGraph`] for batch size `batch`.
pub fn infer_int(g: &IntGraph, batch: usize) -> Result<Vec<Vec<usize>>, ShapeError> {
    if g.nodes.is_empty() {
        return Err(ShapeError::Empty);
    }
    if batch == 0 {
        return Err(ShapeError::EmptyBatch);
    }
    let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        if !matches!(n.op, IntOp::Input { .. }) && n.inputs.is_empty() {
            return Err(node_err(n.id, &n.name, "non-Input node has no inputs"));
        }
        let shape = match &n.op {
            IntOp::Input { shape, .. } => {
                let mut s = vec![batch];
                s.extend_from_slice(shape);
                s
            }
            IntOp::ConvInt { wq, cin, kh, kw, stride, pad, .. } => {
                let x = nth(&shapes, &n.inputs, 0);
                if x.len() != 4 {
                    return Err(node_err(n.id, &n.name, "conv on non-NCHW input"));
                }
                if x[1] != *cin {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!("weights expect {cin} input channels, got {}", x[1]),
                    ));
                }
                if wq.shape()[0] != cin * kh * kw {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!(
                            "weight matrix rows {} != cin*kh*kw {}",
                            wq.shape()[0],
                            cin * kh * kw
                        ),
                    ));
                }
                let co = wq.shape()[1];
                let oh = conv_extent(n.id, &n.name, x[2], *kh, *stride, *pad)?;
                let ow = conv_extent(n.id, &n.name, x[3], *kw, *stride, *pad)?;
                vec![x[0], co, oh, ow]
            }
            IntOp::LinearInt { wq, .. } => {
                let x = nth(&shapes, &n.inputs, 0);
                let (fi, fo) = (wq.shape()[0], wq.shape()[1]);
                if x.len() != 2 || x[1] != fi {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!("linear expects [B, {fi}], got {x:?}"),
                    ));
                }
                vec![x[0], fo]
            }
            IntOp::IntBn { bn } => {
                let x = nth(&shapes, &n.inputs, 0);
                want_channels(n.id, &n.name, x, bn.kappa_q.len(), "IntBn")?;
                x.to_vec()
            }
            IntOp::ThreshAct { th } => {
                let x = nth(&shapes, &n.inputs, 0);
                want_channels(n.id, &n.name, x, th.th.len(), "ThreshAct")?;
                x.to_vec()
            }
            IntOp::RequantAct { .. } => nth(&shapes, &n.inputs, 0).to_vec(),
            IntOp::MaxPoolInt { k } => pool_extents(n.id, &n.name, nth(&shapes, &n.inputs, 0), *k)?,
            IntOp::AvgPoolInt { k, .. } => pool_extents(n.id, &n.name, nth(&shapes, &n.inputs, 0), *k)?,
            IntOp::Flatten => {
                let x = nth(&shapes, &n.inputs, 0);
                vec![x[0], x[1..].iter().product()]
            }
            IntOp::AddRequant { rqs } => {
                if rqs.len() != n.inputs.len() - 1 {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!(
                            "{} requants for {} extra branches",
                            rqs.len(),
                            n.inputs.len() - 1
                        ),
                    ));
                }
                let first = nth(&shapes, &n.inputs, 0).to_vec();
                for (bi, &i) in n.inputs.iter().enumerate().skip(1) {
                    if shapes[i] != first {
                        return Err(node_err(
                            n.id,
                            &n.name,
                            format!(
                                "Add branch {bi} shape {:?} != branch 0 shape {first:?}",
                                shapes[i]
                            ),
                        ));
                    }
                }
                first
            }
        };
        shapes.push(shape);
    }
    Ok(shapes)
}

/// Validate and return every node's stamped storage precision — the
/// propagation half of DESIGN.md §Precision propagation, run by plan
/// compilation before any packed kernel is dispatched. The soundness
/// rules mirror [`IntOp::natural_precision`]:
///
/// * clipped ops (Input / RequantAct / ThreshAct) may carry any stamp
///   whose range contains their provable output range — wider (unpacked)
///   stamps are legal, narrower ones are rejected;
/// * pooling and Flatten must carry exactly their input's precision (the
///   packed kernels copy/compare elements without conversion);
/// * accumulating ops (ConvInt / LinearInt / IntBn / AddRequant) must be
///   `I32` — only the deploy-time range analysis bounds them, and it
///   proves i32, nothing narrower.
pub fn infer_precision(g: &IntGraph) -> Result<Vec<Precision>, ShapeError> {
    let mut precs: Vec<Precision> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let p = n.precision;
        match &n.op {
            IntOp::Input { spec, .. } => {
                if !p.contains(spec.lo, spec.hi) {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!(
                            "stamped precision {} cannot hold the input spec range [{}, {}]",
                            p.name(),
                            spec.lo,
                            spec.hi
                        ),
                    ));
                }
            }
            IntOp::RequantAct { rq } => {
                if !p.contains(rq.lo, rq.hi) {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!(
                            "stamped precision {} cannot hold the requant clip range [{}, {}]",
                            p.name(),
                            rq.lo,
                            rq.hi
                        ),
                    ));
                }
            }
            IntOp::ThreshAct { th } => {
                if !p.contains(0, th.n_levels) {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!(
                            "stamped precision {} cannot hold the threshold range [0, {}]",
                            p.name(),
                            th.n_levels
                        ),
                    ));
                }
            }
            IntOp::MaxPoolInt { .. } | IntOp::AvgPoolInt { .. } | IntOp::Flatten => {
                let Some(&i0) = n.inputs.first() else {
                    return Err(node_err(n.id, &n.name, "pool/flatten has no input"));
                };
                let ip = precs[i0];
                if p != ip {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!(
                            "pool/flatten precision {} must match its input's {}",
                            p.name(),
                            ip.name()
                        ),
                    ));
                }
            }
            IntOp::ConvInt { .. }
            | IntOp::LinearInt { .. }
            | IntOp::IntBn { .. }
            | IntOp::AddRequant { .. } => {
                if p != Precision::I32 {
                    return Err(node_err(
                        n.id,
                        &n.name,
                        format!(
                            "accumulating op stamped {} — only I32 is range-proved",
                            p.name()
                        ),
                    ));
                }
            }
        }
        precs.push(p);
    }
    Ok(precs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bn::BnParams;
    use crate::quant::QuantSpec;
    use crate::tensor::Tensor;

    #[test]
    fn float_conv_chain_shapes() {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 16, 16] }, &[]);
        let w = Tensor::zeros(&[8, 1, 3, 3]);
        let c = g.push("c", Op::Conv2d { w, bias: None, stride: 2, pad: 1 }, &[x]);
        let b = g.push("bn", Op::BatchNorm { bn: BnParams::identity(8) }, &[c]);
        let a = g.push("a", Op::ReLU, &[b]);
        let p = g.push("gap", Op::GlobalAvgPool, &[a]);
        let w2 = Tensor::zeros(&[8, 10]);
        g.push("fc", Op::Linear { w: w2, bias: None }, &[p]);
        let shapes = infer_float(&g, 4).unwrap();
        assert_eq!(shapes[0], vec![4, 1, 16, 16]);
        assert_eq!(shapes[1], vec![4, 8, 8, 8]);
        assert_eq!(shapes[3], vec![4, 8, 8, 8]);
        assert_eq!(shapes[4], vec![4, 8]);
        assert_eq!(shapes[5], vec![4, 10]);
    }

    #[test]
    fn float_rejects_channel_mismatch() {
        let mut g = Graph::new(1.0);
        let x = g.push("in", Op::Input { shape: vec![2, 4, 4] }, &[]);
        let w = Tensor::zeros(&[3, 1, 3, 3]); // expects 1 input channel
        g.push("c", Op::Conv2d { w, bias: None, stride: 1, pad: 1 }, &[x]);
        assert!(infer_float(&g, 1).is_err());
    }

    #[test]
    fn float_rejects_linear_dim_mismatch() {
        let mut g = Graph::new(1.0);
        let x = g.push("in", Op::Input { shape: vec![5] }, &[]);
        let w = Tensor::zeros(&[4, 2]);
        g.push("fc", Op::Linear { w, bias: None }, &[x]);
        assert!(infer_float(&g, 1).is_err());
    }

    #[test]
    fn int_conv_pool_flatten_linear() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0 / 255.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 8, 8], spec }, &[]);
        let wq = Tensor::zeros(&[9, 4]).into(); // 1*3*3 -> 4 channels
        let c = g.push(
            "c",
            IntOp::ConvInt { wq, bias_q: None, cin: 1, kh: 3, kw: 3, stride: 1, pad: 1 },
            &[x],
        );
        let p = g.push("mp", IntOp::MaxPoolInt { k: 2 }, &[c]);
        let f = g.push("fl", IntOp::Flatten, &[p]);
        let wq2 = Tensor::zeros(&[4 * 4 * 4, 10]).into();
        g.push("fc", IntOp::LinearInt { wq: wq2, bias_q: None }, &[f]);
        let shapes = infer_int(&g, 2).unwrap();
        assert_eq!(shapes[1], vec![2, 4, 8, 8]);
        assert_eq!(shapes[2], vec![2, 4, 4, 4]);
        assert_eq!(shapes[3], vec![2, 64]);
        assert_eq!(shapes[4], vec![2, 10]);
    }

    #[test]
    fn int_rejects_pool_indivisible() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 5, 5], spec }, &[]);
        g.push("mp", IntOp::MaxPoolInt { k: 2 }, &[x]);
        assert!(infer_int(&g, 1).is_err());
    }

    #[test]
    fn int_rejects_add_shape_mismatch() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![4], spec }, &[]);
        let wq = Tensor::zeros(&[4, 2]).into();
        let l = g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[x]);
        let rq = crate::quant::requant::Requant { m: 1, d: 0, lo: 0, hi: 255 };
        g.push("add", IntOp::AddRequant { rqs: vec![rq] }, &[x, l]);
        assert!(infer_int(&g, 1).is_err());
    }

    #[test]
    fn empty_batch_rejected() {
        let mut g = Graph::new(1.0);
        g.push("in", Op::Input { shape: vec![4] }, &[]);
        assert!(matches!(infer_float(&g, 0), Err(ShapeError::EmptyBatch)));
    }

    fn packed_chain() -> IntGraph {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0 / 255.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 4, 4], spec }, &[]);
        let wq = Tensor::zeros(&[9, 2]).into();
        let c = g.push(
            "c",
            IntOp::ConvInt { wq, bias_q: None, cin: 1, kh: 3, kw: 3, stride: 1, pad: 1 },
            &[x],
        );
        let rq = crate::quant::requant::Requant { m: 1, d: 0, lo: 0, hi: 255 };
        let a = g.push("a", IntOp::RequantAct { rq }, &[c]);
        g.push("p", IntOp::MaxPoolInt { k: 2 }, &[a]);
        g
    }

    #[test]
    fn precision_inference_accepts_natural_stamps() {
        let g = packed_chain();
        let precs = infer_precision(&g).unwrap();
        assert_eq!(
            precs,
            vec![Precision::U8, Precision::I32, Precision::U8, Precision::U8]
        );
    }

    #[test]
    fn precision_inference_accepts_widened_stamps() {
        // Unpacking a requant to I32 is sound (just wasteful).
        let mut g = packed_chain();
        g.stamp_precision(2, Precision::I32);
        g.stamp_precision(3, Precision::I32); // pool must follow its input
        assert!(infer_precision(&g).is_ok());
    }

    #[test]
    fn precision_inference_rejects_unsound_stamps() {
        // A u8 stamp on an unbounded conv accumulator is unsound.
        let mut g = packed_chain();
        g.stamp_precision(1, Precision::U8);
        assert!(infer_precision(&g).is_err());

        // A pool whose precision diverges from its input is rejected.
        let mut g = packed_chain();
        g.stamp_precision(3, Precision::I32);
        assert!(infer_precision(&g).is_err());

        // An i8 stamp cannot hold a [0, 255] requant clip.
        let mut g = packed_chain();
        g.stamp_precision(2, Precision::I8);
        assert!(infer_precision(&g).is_err());
    }
}
