//! Finite-difference validation of the backward-plan compiler: for
//! randomized small float graphs, the analytic parameter gradients from
//! `BackwardPlan` must match central differences of the scalar loss
//! L = <p, y(θ)> (p a fixed random projection of the network output)
//! within a relative-error bound.
//!
//! ReLU and MaxPool are only piecewise differentiable: a component whose
//! one-sided differences disagree has a kink inside [θ−h, θ+h] and is
//! skipped, but a minimum fraction of components must survive for a
//! check to count. PACT's staircase forward is *not* FD-testable (its
//! gradient is the STE by construction) — its analytic gradients are
//! unit-tested in `engine::backward` instead.

use nemo::engine::{BackwardPlan, FloatArena, FloatEngine, FloatPlan};
use nemo::graph::grad::{gather_params, param_refs, scatter_params};
use nemo::graph::{Graph, Op};
use nemo::quant::bn::BnParams;
use nemo::tensor::{Tensor, TensorF};
use nemo::util::rng::Rng;

fn rand_w(rng: &mut Rng, shape: &[usize]) -> TensorF {
    let n: usize = shape.iter().product();
    TensorF::from_vec(shape, (0..n).map(|_| rng.normal(0.0, 0.5) as f32).collect())
}

fn rand_bias(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal(0.0, 0.2)).collect()
}

fn rand_bn(rng: &mut Rng, c: usize) -> BnParams {
    BnParams {
        gamma: (0..c).map(|_| rng.uniform(0.5, 1.5)).collect(),
        sigma: (0..c).map(|_| rng.uniform(0.7, 1.3)).collect(),
        beta: (0..c).map(|_| rng.normal(0.0, 0.1)).collect(),
        mu: (0..c).map(|_| rng.normal(0.0, 0.1)).collect(),
    }
}

fn rand_x(rng: &mut Rng, shape: &[usize]) -> TensorF {
    let n: usize = shape.iter().product();
    TensorF::from_vec(shape, (0..n).map(|_| rng.uniform(0.0, 1.0) as f32).collect())
}

/// L = <p, y(θ)> via the (unfused, always-available) float interpreter.
fn loss(g: &Graph, x: &TensorF, p: &[f64]) -> f64 {
    let y = FloatEngine::new().run(g, x);
    y.data().iter().zip(p).map(|(&v, &pv)| v as f64 * pv).sum()
}

/// Flat analytic parameter gradients of L = <p, y(θ)> from the backward
/// plan (seed dL/dy = p).
fn analytic_grads(g: &Graph, x: &TensorF, p: &[f64]) -> Vec<f64> {
    let batch = x.shape()[0];
    let fwd = FloatPlan::compile_unfused(g).unwrap();
    let flayout = fwd.layout(batch).unwrap();
    let bwd = BackwardPlan::compile(g).unwrap();
    let blayout = bwd.layout(g, batch).unwrap();
    let mut arena = FloatArena::new();
    let (out, tape) = fwd.execute_checkpointed(&flayout, &mut arena, x, bwd.tape_mask());
    let seed = Tensor::from_vec(out.shape(), p.iter().map(|&v| v as f32).collect());
    let grads = bwd.execute(g, &blayout, &mut arena, &tape, &seed);
    grads.gather(&param_refs(g))
}

/// Central-difference check of every (or a sampled subset of) flat
/// parameter component against the analytic gradient.
fn check_fd(g: &mut Graph, x: &TensorF, seed: u64) {
    g.validate().unwrap();
    let mut rng = Rng::new(seed);
    let y0 = FloatEngine::new().run(g, x);
    let p: Vec<f64> = (0..y0.len()).map(|_| rng.normal(0.0, 1.0)).collect();
    let ga = analytic_grads(g, x, &p);
    let refs = param_refs(g);
    let theta0 = gather_params(g, &refs);
    let n = theta0.len();
    assert_eq!(ga.len(), n);
    let idxs: Vec<usize> = if n <= 80 {
        (0..n).collect()
    } else {
        (0..80).map(|_| rng.int(0, n as i64) as usize).collect()
    };
    let l0 = loss(g, x, &p);
    let mut checked = 0usize;
    for &i in &idxs {
        // h scaled to the parameter; large enough to stay above the f32
        // forward's rounding noise, small enough for O(h^2) curvature.
        let h = 5e-3 * theta0[i].abs().max(1.0);
        let mut th = theta0.clone();
        th[i] = theta0[i] + h;
        scatter_params(g, &refs, &th);
        let lp = loss(g, x, &p);
        th[i] = theta0[i] - h;
        scatter_params(g, &refs, &th);
        let lm = loss(g, x, &p);
        th[i] = theta0[i];
        scatter_params(g, &refs, &th);
        // disagreeing one-sided differences => a ReLU/MaxPool kink (or
        // a max-pool argmax flip) inside the stencil: skip the component
        let d_plus = (lp - l0) / h;
        let d_minus = (l0 - lm) / h;
        let kink_scale = d_plus.abs().max(d_minus.abs()).max(1.0);
        if (d_plus - d_minus).abs() > 0.02 * kink_scale {
            continue;
        }
        checked += 1;
        let central = (lp - lm) / (2.0 * h);
        let err = (central - ga[i]).abs();
        // 2% relative, plus the worst-case residual of a kink small
        // enough to pass the filter (|d+ − d−|/2 ≤ 0.01·kink_scale) and
        // the f32 forward's rounding noise.
        let tol = 2e-2 * central.abs().max(ga[i].abs()) + 0.012 * kink_scale;
        assert!(
            err <= tol,
            "seed {seed} component {i}: analytic {} vs FD {central} (err {err:.3e} > tol {tol:.3e})",
            ga[i]
        );
    }
    // the kink filter must not hollow the test out
    assert!(
        checked * 3 >= idxs.len() * 2,
        "seed {seed}: only {checked}/{} components were smooth enough to check",
        idxs.len()
    );
}

/// conv(+bias) -> bn -> relu -> gap -> fc(+bias) on a 6x6 input.
fn conv_bn_relu_gap_fc(rng: &mut Rng) -> (Graph, TensorF) {
    let mut g = Graph::new(1.0 / 255.0);
    let x = g.push("in", Op::Input { shape: vec![1, 6, 6] }, &[]);
    let w = rand_w(rng, &[4, 1, 3, 3]);
    let bias = Some(rand_bias(rng, 4));
    let c = g.push("conv", Op::Conv2d { w, bias, stride: 1, pad: 1 }, &[x]);
    let b = g.push("bn", Op::BatchNorm { bn: rand_bn(rng, 4) }, &[c]);
    let a = g.push("act", Op::ReLU, &[b]);
    let gp = g.push("gap", Op::GlobalAvgPool, &[a]);
    let wf = rand_w(rng, &[4, 3]);
    g.push("fc", Op::Linear { w: wf, bias: Some(rand_bias(rng, 3)) }, &[gp]);
    (g, rand_x(rng, &[2, 1, 6, 6]))
}

/// Flat-input MLP: linear -> relu -> linear (exercises the Input-node
/// tape entry feeding a Linear weight gradient directly).
fn mlp(rng: &mut Rng) -> (Graph, TensorF) {
    let mut g = Graph::new(1.0 / 255.0);
    let x = g.push("in", Op::Input { shape: vec![5] }, &[]);
    let w1 = rand_w(rng, &[5, 7]);
    let l1 = g.push("fc1", Op::Linear { w: w1, bias: Some(rand_bias(rng, 7)) }, &[x]);
    let a = g.push("act", Op::ReLU, &[l1]);
    let w2 = rand_w(rng, &[7, 4]);
    g.push("fc2", Op::Linear { w: w2, bias: None }, &[a]);
    (g, rand_x(rng, &[3, 5]))
}

/// Two conv stages with max pooling, a strided conv, and a flatten.
fn conv_pool_conv_flatten_fc(rng: &mut Rng) -> (Graph, TensorF) {
    let mut g = Graph::new(1.0 / 255.0);
    let x = g.push("in", Op::Input { shape: vec![1, 8, 8] }, &[]);
    let w1 = rand_w(rng, &[3, 1, 3, 3]);
    let c1 = g.push("c1", Op::Conv2d { w: w1, bias: None, stride: 1, pad: 1 }, &[x]);
    let a1 = g.push("a1", Op::ReLU, &[c1]);
    let mp = g.push("mp", Op::MaxPool { k: 2 }, &[a1]);
    let w2 = rand_w(rng, &[4, 3, 3, 3]);
    let c2 = g.push("c2", Op::Conv2d { w: w2, bias: None, stride: 2, pad: 1 }, &[mp]);
    let b2 = g.push("bn2", Op::BatchNorm { bn: rand_bn(rng, 4) }, &[c2]);
    let a2 = g.push("a2", Op::ReLU, &[b2]);
    let fl = g.push("fl", Op::Flatten, &[a2]);
    let wf = rand_w(rng, &[4 * 2 * 2, 3]);
    g.push("fc", Op::Linear { w: wf, bias: Some(rand_bias(rng, 3)) }, &[fl]);
    (g, rand_x(rng, &[2, 1, 8, 8]))
}

/// Residual: a branch point at an activation and an Add join
/// (the fan-out > 1 accumulation path of the backward plan).
fn residual_add(rng: &mut Rng) -> (Graph, TensorF) {
    let mut g = Graph::new(1.0 / 255.0);
    let x = g.push("in", Op::Input { shape: vec![1, 6, 6] }, &[]);
    let w0 = rand_w(rng, &[3, 1, 3, 3]);
    let c0 = g.push("c0", Op::Conv2d { w: w0, bias: None, stride: 1, pad: 1 }, &[x]);
    let b0 = g.push("bn0", Op::BatchNorm { bn: rand_bn(rng, 3) }, &[c0]);
    let a0 = g.push("a0", Op::ReLU, &[b0]);
    let w1 = rand_w(rng, &[3, 3, 3, 3]);
    let c1 = g.push("c1", Op::Conv2d { w: w1, bias: None, stride: 1, pad: 1 }, &[a0]);
    let b1 = g.push("bn1", Op::BatchNorm { bn: rand_bn(rng, 3) }, &[c1]);
    let a1 = g.push("a1", Op::ReLU, &[b1]);
    let add = g.push("add", Op::Add, &[a0, a1]);
    let a2 = g.push("a2", Op::ReLU, &[add]);
    let gp = g.push("gap", Op::GlobalAvgPool, &[a2]);
    let wf = rand_w(rng, &[3, 3]);
    g.push("fc", Op::Linear { w: wf, bias: None }, &[gp]);
    (g, rand_x(rng, &[2, 1, 6, 6]))
}

/// Average pooling (everywhere-differentiable pooling path).
fn conv_avgpool_fc(rng: &mut Rng) -> (Graph, TensorF) {
    let mut g = Graph::new(1.0 / 255.0);
    let x = g.push("in", Op::Input { shape: vec![1, 8, 8] }, &[]);
    let w1 = rand_w(rng, &[3, 1, 3, 3]);
    let c1 = g.push("c1", Op::Conv2d { w: w1, bias: None, stride: 1, pad: 1 }, &[x]);
    let b1 = g.push("bn1", Op::BatchNorm { bn: rand_bn(rng, 3) }, &[c1]);
    let a1 = g.push("a1", Op::ReLU, &[b1]);
    let ap = g.push("ap", Op::AvgPool { k: 2 }, &[a1]);
    let fl = g.push("fl", Op::Flatten, &[ap]);
    let wf = rand_w(rng, &[3 * 4 * 4, 2]);
    g.push("fc", Op::Linear { w: wf, bias: Some(rand_bias(rng, 2)) }, &[fl]);
    (g, rand_x(rng, &[2, 1, 8, 8]))
}

#[test]
fn fd_conv_bn_relu_gap_fc() {
    for seed in [11u64, 12, 13] {
        let mut rng = Rng::new(seed);
        let (mut g, x) = conv_bn_relu_gap_fc(&mut rng);
        check_fd(&mut g, &x, seed);
    }
}

#[test]
fn fd_mlp() {
    for seed in [21u64, 22, 23] {
        let mut rng = Rng::new(seed);
        let (mut g, x) = mlp(&mut rng);
        check_fd(&mut g, &x, seed);
    }
}

#[test]
fn fd_conv_pool_conv_flatten_fc() {
    for seed in [31u64, 32] {
        let mut rng = Rng::new(seed);
        let (mut g, x) = conv_pool_conv_flatten_fc(&mut rng);
        check_fd(&mut g, &x, seed);
    }
}

#[test]
fn fd_residual_add() {
    for seed in [41u64, 42] {
        let mut rng = Rng::new(seed);
        let (mut g, x) = residual_add(&mut rng);
        check_fd(&mut g, &x, seed);
    }
}

#[test]
fn fd_conv_avgpool_fc() {
    for seed in [51u64, 52] {
        let mut rng = Rng::new(seed);
        let (mut g, x) = conv_avgpool_fc(&mut rng);
        check_fd(&mut g, &x, seed);
    }
}

#[test]
fn fd_synthnet_fp_graph_samples() {
    // The real model, FD-checked on a sampled subset of its ~6k params.
    let mut rng = Rng::new(61);
    let net = nemo::model::synthnet::SynthNet::init(&mut rng);
    let mut g = net.to_fp_graph();
    let x = rand_x(&mut rng, &[2, 1, 16, 16]);
    check_fd(&mut g, &x, 61);
}
