"""NEMO quantization math in JAX (build-time library).

Implements the formal model of Conti, "Technical Report: NEMO Quantization
for Deployment Model" (2020):

  * PACT fake-quantization of activations (eq. in sec. 2.2) with the
    straight-through estimator (STE), including the PACT gradient w.r.t.
    the clipping bound beta.
  * Symmetric PACT-like fake-quantization of weights with STE.
  * Requantization  RQ(q) = floor(eps_a * 2^d / eps_b) * q >> d
    (Def. 3.1, Eq. 12-14), with d chosen from a relative-error target
    eta = 1/requantization_factor.
  * Quantized batch-norm  Q(phi) = Q(kappa) * Q(varphi) + Q(lambda)
    (Eq. 21-22) with symmetric quantization of kappa and lambda stored
    directly in the target format (the "deployment backend" choice the
    paper explicitly allows, sec. 3.4).
  * Threshold merging of BN + linear quantization (Eq. 19-20) - exact.
  * Integer average pooling (Eq. 25).

Conventions (mirrored bit-exactly by the Rust side, rust/src/quant/):

  * activations: alpha = 0, eps_y = beta_y / (2^Q - 1),
    integer image in [0, 2^Q - 1].
  * weights: symmetric grid, eps_w = 2*beta_w / (2^Q - 1),
    integer image in [-2^(Q-1), 2^(Q-1) - 1]; the offset alpha_w is a
    multiple of eps_w so the correction term of Eq. 15 folds into a
    single integer image (this is what NEMO's integerize does).
  * all "floor" operations on integer images are arithmetic right
    shifts (floor toward -inf), matching two's-complement >> in Rust.
  * d is computed by an exact doubling loop, NOT log2, so that Rust and
    Python derive identical d from identical f64 inputs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

# Integer dtype used for integer images. Accumulations that can exceed
# 2^31 (the requant multiply, kappa*phi products) are widened to int64
# locally and narrowed back after clipping.
INT = jnp.int32
WIDE = jnp.int64

# ---------------------------------------------------------------------------
# Quantum / space bookkeeping (scalar, python-side: runs at transform time)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """A quantized space Z_t with its quantum (Def. 2.1).

    eps: the quantum epsilon_t (f64 scalar).
    lo, hi: inclusive integer bounds of Z_t.
    """

    eps: float
    lo: int
    hi: int

    @property
    def levels(self) -> int:
        return self.hi - self.lo + 1

    @staticmethod
    def activation(beta: float, bits: int) -> "QuantSpec":
        """alpha=0 activation space: eps = beta/(2^Q - 1), Z = [0, 2^Q-1]."""
        n = (1 << bits) - 1
        return QuantSpec(eps=beta / n, lo=0, hi=n)

    @staticmethod
    def weight(beta: float, bits: int) -> "QuantSpec":
        """Symmetric weight space: eps = 2*beta/(2^Q - 1)."""
        n = (1 << bits) - 1
        return QuantSpec(eps=2.0 * beta / n, lo=-(1 << (bits - 1)), hi=(1 << (bits - 1)) - 1)

    @staticmethod
    def symmetric(beta: float, bits: int) -> "QuantSpec":
        """Symmetric space used for BN kappa (sec. 3.4): eps = 2*beta/(2^Q-1)."""
        n = (1 << bits) - 1
        return QuantSpec(eps=2.0 * beta / n, lo=-(1 << (bits - 1)), hi=(1 << (bits - 1)) - 1)


def choose_d(eps_a: float, eps_b: float, requantization_factor: int = 16,
             d_max: int = 40) -> int:
    """Smallest d with 2^d >= requantization_factor * eps_b / eps_a (Eq. 14).

    Uses an exact doubling loop (not log2) so Rust derives the same d from
    the same f64 inputs. Raises when the bound is unreachable within d_max
    doublings (mirrors Rust's typed RequantSaturation error): a saturated
    d would bake a requant ratio violating the 1/eta error guarantee.
    """
    assert eps_a > 0.0 and eps_b > 0.0
    target = requantization_factor * eps_b
    d = 0
    p = eps_a  # eps_a * 2^d, exact doubling
    while p < target and d < d_max:
        p *= 2.0
        d += 1
    if p < target:
        raise ValueError(
            f"choose_d saturated: eps_a={eps_a:.3e}, eps_b={eps_b:.3e}, "
            f"factor={requantization_factor} needs d > {d_max} (Eq. 14)")
    return d


def requant_multiplier(eps_a: float, eps_b: float, d: int) -> int:
    """m = floor(eps_a * 2^d / eps_b)  (Eq. 13)."""
    return int(math.floor(eps_a * float(1 << d) / eps_b))


# ---------------------------------------------------------------------------
# Fake quantization with STE (FakeQuantized representation, sec. 2.2)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def pact_act(x: jax.Array, beta: jax.Array, eps: jax.Array) -> jax.Array:
    """PACT activation fake-quantization.

    y = floor(clip(x, 0, beta) / eps) * eps     (sec. 2.2, "In NEMO")

    The clip keeps the integer image within [0, beta/eps]; eps is passed
    explicitly (eps = beta / (2^Q - 1)) so the same primitive serves both
    trainable-beta and frozen-beta uses.
    """
    y = jnp.clip(x, 0.0, beta)
    return jnp.floor(y / eps) * eps


def _pact_act_fwd(x, beta, eps):
    return pact_act(x, beta, eps), (x, beta)


def _pact_act_bwd(res, g):
    x, beta = res
    # STE: grad wrt x passes where 0 <= x < beta (indicator chi_[0,beta)).
    in_range = jnp.logical_and(x >= 0.0, x < beta)
    gx = jnp.where(in_range, g, 0.0)
    # PACT gradient wrt beta: 1 where x >= beta (clipped at the top).
    gbeta = jnp.sum(jnp.where(x >= beta, g, 0.0))
    return gx, gbeta.reshape(jnp.shape(beta)), None


pact_act.defvjp(_pact_act_fwd, _pact_act_bwd)


@jax.custom_vjp
def pact_weight(w: jax.Array, beta: jax.Array, bits: int) -> jax.Array:
    """Symmetric PACT-like weight fake-quantization with STE.

    eps_w = 2*beta/(2^Q-1); w_hat = clip_int(floor(w/eps)) * eps.
    """
    n = (1 << bits) - 1
    eps = 2.0 * beta / n
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    q = jnp.clip(jnp.floor(w / eps), lo, hi)
    return q * eps


def _pact_weight_fwd(w, beta, bits):
    return pact_weight(w, beta, bits), (w, beta)


def _pact_weight_bwd(res, g):
    w, beta = res
    # STE on the clipping interval [-beta, beta).
    in_range = jnp.logical_and(w >= -beta, w < beta)
    gw = jnp.where(in_range, g, 0.0)
    return gw, None, None


pact_weight.defvjp(_pact_weight_fwd, _pact_weight_bwd)


def quantize_weight_image(w: jax.Array, beta: float, bits: int) -> jax.Array:
    """Integer image Q_w(w) of a weight tensor (used at integerize time)."""
    spec = QuantSpec.weight(beta, bits)
    q = jnp.clip(jnp.floor(w / spec.eps), spec.lo, spec.hi)
    return q.astype(INT)


def quantize_act_image(x: jax.Array, beta: float, bits: int) -> jax.Array:
    """Integer image Q_y(x) of an (already non-negative) activation tensor."""
    spec = QuantSpec.activation(beta, bits)
    q = jnp.clip(jnp.floor(x / spec.eps), spec.lo, spec.hi)
    return q.astype(INT)


# ---------------------------------------------------------------------------
# Integer-domain primitives (IntegerDeployable representation, sec. 3)
# ---------------------------------------------------------------------------


def requant(q: jax.Array, m: jax.Array, d: jax.Array,
            lo: int | jax.Array, hi: int | jax.Array) -> jax.Array:
    """RQ + clip: clip((m * q) >> d, lo, hi)  (Eq. 11 / Eq. 13).

    The multiply is widened to int64: m*q can exceed 2^31 (m is around
    requantization_factor..2*requantization_factor but q after integer BN
    can reach ~2^28). The arithmetic right shift floors toward -inf,
    matching the floor() in Eq. 13 for negative values too.
    """
    wide = q.astype(WIDE) * jnp.asarray(m, WIDE)
    shifted = jnp.right_shift(wide, jnp.asarray(d, WIDE))
    return jnp.clip(shifted, jnp.asarray(lo, WIDE), jnp.asarray(hi, WIDE)).astype(INT)


def integer_bn(q: jax.Array, kappa_q: jax.Array, lambda_q: jax.Array) -> jax.Array:
    """Q(phi) = Q(kappa) * Q(varphi) + Q(lambda)  (Eq. 22), per-channel.

    kappa_q, lambda_q have shape [C]; q has layout NCHW (or [N, C] for
    linear). Accumulation is widened to int64, the caller requantizes.
    """
    c = kappa_q.shape[0]
    if q.ndim == 4:
        kq = kappa_q.reshape(1, c, 1, 1).astype(WIDE)
        lq = lambda_q.reshape(1, c, 1, 1).astype(WIDE)
    elif q.ndim == 2:
        kq = kappa_q.reshape(1, c).astype(WIDE)
        lq = lambda_q.reshape(1, c).astype(WIDE)
    else:
        raise ValueError(f"integer_bn: unsupported rank {q.ndim}")
    return q.astype(WIDE) * kq + lq


def threshold_act(q: jax.Array, thresholds: jax.Array) -> jax.Array:
    """Q_y(varphi) = sum_i i * chi_[TH_i, TH_{i+1})(Q(varphi))  (Eq. 20).

    thresholds has shape [C, N] (per-channel because BN parameters are
    per-channel): output integer = number of thresholds <= q, i.e. the
    staircase of Eq. 20 with TH_0 = -inf implied by clipping at 0.
    """
    c, n = thresholds.shape
    if q.ndim == 4:
        qe = q[:, :, :, :, None]  # [N, C, H, W, 1]
        th = thresholds.reshape(1, c, 1, 1, n)
    elif q.ndim == 2:
        qe = q[:, :, None]
        th = thresholds.reshape(1, c, n)
    else:
        raise ValueError(f"threshold_act: unsupported rank {q.ndim}")
    return jnp.sum((qe >= th).astype(INT), axis=-1) - 1


def avgpool_requant(acc: jax.Array, k1: int, k2: int, d: int) -> jax.Array:
    """Integer average pooling scaling (Eq. 25): (floor(2^d/(K1*K2))*acc) >> d."""
    m = (1 << d) // (k1 * k2)
    wide = acc.astype(WIDE) * jnp.asarray(m, WIDE)
    return jnp.right_shift(wide, jnp.asarray(d, WIDE)).astype(INT)


# ---------------------------------------------------------------------------
# Transform-time parameter derivation (python mirror of rust/src/transform/)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BnQuantParams:
    """Quantized batch-norm parameters (sec. 3.4, Integer BN)."""

    kappa_q: Sequence[int]
    lambda_q: Sequence[int]
    eps_kappa: float
    eps_phi_out: float  # eps_kappa * eps_phi_in


def quantize_bn(gamma, sigma, beta, mu, eps_phi: float, kappa_bits: int = 8):
    """Derive (Q(kappa), Q(lambda)) from BN parameters (Eq. 21).

    kappa = gamma/sigma quantized symmetrically with kappa_bits;
    lambda = beta - kappa*mu stored directly in the target format
    eps_kappa*eps_phi (D=1 wiring; the paper leaves this to the backend).
    """
    import numpy as np

    gamma = np.asarray(gamma, np.float64)
    sigma = np.asarray(sigma, np.float64)
    beta = np.asarray(beta, np.float64)
    mu = np.asarray(mu, np.float64)
    kappa = gamma / sigma
    lam = beta - kappa * mu
    bmax = float(np.max(np.abs(kappa)))
    if bmax == 0.0:
        bmax = 1.0
    spec = QuantSpec.symmetric(bmax, kappa_bits)
    kappa_q = np.clip(np.floor(kappa / spec.eps), spec.lo, spec.hi).astype(np.int64)
    eps_phi_out = spec.eps * eps_phi
    lambda_q = np.floor(lam / eps_phi_out).astype(np.int64)
    return BnQuantParams(
        kappa_q=[int(v) for v in kappa_q],
        lambda_q=[int(v) for v in lambda_q],
        eps_kappa=spec.eps,
        eps_phi_out=eps_phi_out,
    )


def bn_thresholds(gamma, sigma, beta, mu, eps_phi: float, eps_y: float,
                  n_levels: int):
    """Integer thresholds TH_i of Eq. 19 (exact BN+act merge), per channel.

    TH_i = ceil( (sigma/gamma * i * eps_y - beta*sigma/gamma + mu) / eps_phi )
    for i = 1..n_levels-1 (TH_0 is implied by clipping at integer 0).
    Requires gamma/sigma > 0 (paper assumption).
    """
    import numpy as np

    gamma = np.asarray(gamma, np.float64)
    sigma = np.asarray(sigma, np.float64)
    beta = np.asarray(beta, np.float64)
    mu = np.asarray(mu, np.float64)
    inv = sigma / gamma  # > 0 by assumption
    i = np.arange(1, n_levels)[None, :]  # [1, N-1]
    th = (inv[:, None] * i * eps_y - (beta * inv)[:, None] + mu[:, None]) / eps_phi
    return np.ceil(th).astype(np.int64)


def fold_bn(w, b, gamma, sigma, beta, mu):
    """BN folding (Eq. 18): w <- gamma/sigma * w ; b <- b + beta - gamma/sigma*mu.

    w layout: [C_out, ...]; all BN params have shape [C_out].
    """
    import numpy as np

    w = np.asarray(w, np.float64)
    k = np.asarray(gamma, np.float64) / np.asarray(sigma, np.float64)
    shape = (-1,) + (1,) * (w.ndim - 1)
    w_f = w * k.reshape(shape)
    b0 = np.zeros_like(k) if b is None else np.asarray(b, np.float64)
    b_f = b0 + np.asarray(beta, np.float64) - k * np.asarray(mu, np.float64)
    return w_f, b_f
