//! Coordinator integration tests: correctness under concurrency, batching
//! behaviour, failure handling. Requires artifacts (skips otherwise).

use std::time::Duration;

use nemo::coordinator::{ModelVariant, Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::engine::IntegerEngine;
use nemo::io::artifacts_dir;
use nemo::model::artifact_args::synthnet_id_args;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::quant::quantize_input;
use nemo::runtime::Runtime;
use nemo::transform::{deploy, DeployOptions};
use nemo::util::rng::Rng;

fn setup() -> Option<(Runtime, nemo::transform::Deployed)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    let rt = Runtime::new(dir).unwrap();
    let mut rng = Rng::new(31);
    let net = SynthNet::init(&mut rng);
    let dep = deploy(&net.to_pact_graph(8), DeployOptions::default()).unwrap();
    Some((rt, dep))
}

fn start_server(rt: &Runtime, dep: &nemo::transform::Deployed, cfg: ServerConfig) -> Server {
    let base_args = synthnet_id_args(dep).unwrap();
    let model = ModelVariant::load(rt, "synthnet", "id_fwd", base_args).unwrap();
    Server::start(vec![model], cfg)
}

#[test]
fn served_results_match_local_engine_exactly() {
    let Some((rt, dep)) = setup() else { return };
    let server = start_server(&rt, &dep, ServerConfig::default());
    let h = server.handle();
    let engine = IntegerEngine::new();
    let mut data = SynthDigits::new(32);
    for _ in 0..32 {
        let (x, _) = data.batch(1);
        let qx = quantize_input(&x, EPS_IN);
        let served = h.infer("synthnet", qx.clone()).unwrap();
        let local = engine.run(&dep.id, &qx);
        assert_eq!(served.data(), local.data(), "serving must not change results");
    }
    let m = server.stop();
    assert_eq!(m.completed, 32);
    assert_eq!(m.failed, 0);
}

#[test]
fn concurrent_clients_all_get_correct_answers() {
    let Some((rt, dep)) = setup() else { return };
    let server = start_server(
        &rt,
        &dep,
        ServerConfig { max_batch: 16, batch_timeout: Duration::from_micros(400), n_workers: 2 },
    );
    let dep = std::sync::Arc::new(dep);
    let mut joins = Vec::new();
    for c in 0..8u64 {
        let h = server.handle();
        let dep = dep.clone();
        joins.push(std::thread::spawn(move || {
            let engine = IntegerEngine::new();
            let mut data = SynthDigits::new(100 + c);
            for _ in 0..24 {
                let (x, _) = data.batch(1);
                let qx = quantize_input(&x, EPS_IN);
                let served = h.infer("synthnet", qx.clone()).unwrap();
                let local = engine.run(&dep.id, &qx);
                assert_eq!(served.data(), local.data());
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let mut m = server.stop();
    assert_eq!(m.completed, 8 * 24);
    // with 8 concurrent clients the batcher should coalesce
    assert!(
        m.batch_sizes.mean() > 1.0,
        "batcher never batched: mean {}",
        m.batch_sizes.mean()
    );
}

#[test]
fn unknown_model_is_rejected_not_hung() {
    let Some((rt, dep)) = setup() else { return };
    let server = start_server(&rt, &dep, ServerConfig::default());
    let h = server.handle();
    let qx = nemo::tensor::TensorI::zeros(&[1, 1, 16, 16]);
    let err = h.infer("nonexistent", qx).unwrap_err();
    assert!(err.to_string().contains("unknown model"));
    server.stop();
}

#[test]
fn batch_variant_selection_pads_correctly() {
    // 3 requests -> the b=4 variant with 1 padded sample; results for the
    // 3 real samples must be identical to local execution.
    let Some((rt, dep)) = setup() else { return };
    let server = start_server(
        &rt,
        &dep,
        ServerConfig { max_batch: 4, batch_timeout: Duration::from_millis(20), n_workers: 1 },
    );
    let engine = IntegerEngine::new();
    let mut data = SynthDigits::new(33);
    let mut handles = Vec::new();
    for _ in 0..3 {
        let (x, _) = data.batch(1);
        let qx = quantize_input(&x, EPS_IN);
        let h = server.handle();
        let qx2 = qx.clone();
        handles.push((qx, std::thread::spawn(move || h.infer("synthnet", qx2).unwrap())));
    }
    for (qx, j) in handles {
        let served = j.join().unwrap();
        let local = engine.run(&dep.id, &qx);
        assert_eq!(served.data(), local.data());
    }
    let m = server.stop();
    assert_eq!(m.completed, 3);
}
