//! Serving demo (experiment E8): the coordinator batching requests over
//! an [`Executor`] backend, swept over batching configurations.
//!
//!     cargo run --release --example serve_quantized
//!     cargo run --release --features pjrt --example serve_quantized -- --backend pjrt
//!
//! `--backend native` (the default) serves the in-process integer engine
//! — no artifacts needed. `--backend pjrt` serves the AOT-compiled
//! IntegerDeployable executables through the identical coordinator path.
//! Prints a latency/throughput table per (max_batch, clients) point —
//! the data behind EXPERIMENTS.md E8.

use std::sync::Arc;
use std::time::{Duration, Instant};

use nemo::cli::Args;
use nemo::coordinator::{Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::exec::Executor;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::quantize_input;
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

#[cfg(feature = "pjrt")]
fn pjrt_exec(nid: &Network<IntegerDeployable>) -> anyhow::Result<Arc<dyn Executor>> {
    use nemo::model::artifact_args::synthnet_id_args;
    let rt = nemo::runtime::Runtime::new(nemo::io::artifacts_dir())?;
    let base_args = synthnet_id_args(nid.deployed())?;
    let kind = if rt.manifest.by_kind("id_fwd_xla").is_empty() {
        "id_fwd"
    } else {
        "id_fwd_xla"
    };
    Ok(Arc::new(nemo::exec::PjrtExecutor::load(&rt, kind, base_args)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_exec(_nid: &Network<IntegerDeployable>) -> anyhow::Result<Arc<dyn Executor>> {
    anyhow::bail!(
        "built without the `pjrt` feature; rerun with \
         `cargo run --features pjrt --example serve_quantized -- --backend pjrt`"
    )
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        &std::iter::once("serve_quantized".to_string())
            .chain(argv)
            .collect::<Vec<_>>(),
    )?;

    let mut rng = Rng::new(4);
    let net = SynthNet::init(&mut rng);
    let nid = net.to_network(8)?.deploy(DeployOptions::default())?.integerize();

    // Deploy once, serve anywhere: freeze the IntegerDeployable network
    // into a native artifact, reload it, and prove the loaded program is
    // bit-identical before serving from it.
    let artifact = std::env::temp_dir()
        .join(format!("serve_quantized_{}.nemo.json", std::process::id()));
    nid.save_deployed(&artifact)?;
    let loaded = Network::<IntegerDeployable>::load_deployed(&artifact)?;
    let bytes = std::fs::metadata(&artifact).map(|m| m.len()).unwrap_or(0);
    let _ = std::fs::remove_file(&artifact); // loaded fully into memory
    {
        let mut data = SynthDigits::new(77);
        let (x, _) = data.batch(8);
        let qx = quantize_input(&x, EPS_IN);
        anyhow::ensure!(
            nid.run(&qx) == loaded.run(&qx),
            "loaded artifact logits diverged from the in-memory network"
        );
    }
    println!(
        "artifact round-trip: {} ({bytes} bytes, logits bit-identical)",
        artifact.display()
    );

    let backend = args.str_or("backend", "native");
    let exec: Arc<dyn Executor> = match backend.as_str() {
        // Native serving runs the *loaded* artifact — the same path
        // `nemo serve --model m.nemo.json` takes in production.
        "native" => Arc::new(loaded.to_executor(16)?),
        "pjrt" => pjrt_exec(&nid)?,
        b => anyhow::bail!("unknown backend '{b}' (expected native|pjrt)"),
    };
    println!("backend: {}", exec.name());

    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "max_batch", "clients", "p50 (ms)", "p95 (ms)", "p99 (ms)", "thruput r/s", "mean batch"
    );
    let n_requests = 1024usize;
    for max_batch in [1usize, 4, 16] {
        for clients in [1usize, 8, 32] {
            let server = Server::builder()
                .default_config(ServerConfig {
                    max_batch,
                    batch_timeout: Duration::from_micros(300),
                    n_workers: 2,
                })
                .model("synthnet", exec.clone())
                .start()?;
            let t0 = Instant::now();
            let mut joins = Vec::new();
            for c in 0..clients {
                let h = server.handle();
                let per = n_requests / clients;
                joins.push(std::thread::spawn(move || {
                    let mut data = SynthDigits::new(500 + c as u64);
                    for _ in 0..per {
                        let (x, _) = data.batch(1);
                        let qx = quantize_input(&x, EPS_IN);
                        h.infer("synthnet", qx).expect("infer");
                    }
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
            let wall = t0.elapsed().as_secs_f64();
            let mut m = server.stop();
            println!(
                "{:<10} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>12.0} {:>10.2}",
                max_batch,
                clients,
                m.e2e_latency.percentile(0.50) * 1e3,
                m.e2e_latency.percentile(0.95) * 1e3,
                m.e2e_latency.percentile(0.99) * 1e3,
                m.throughput(wall),
                m.batch_sizes.mean()
            );
        }
    }
    Ok(())
}
