//! Serving demo (experiment E8): the coordinator batching requests over
//! the AOT-compiled IntegerDeployable executables, swept over batching
//! configurations.
//!
//!     make artifacts && cargo run --release --example serve_quantized
//!
//! Prints a latency/throughput table per (max_batch, clients) point —
//! the data behind EXPERIMENTS.md E8.

use std::time::{Duration, Instant};

use nemo::coordinator::{ModelVariant, Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::io::artifacts_dir;
use nemo::model::artifact_args::synthnet_id_args;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::quant::quantize_input;
use nemo::runtime::Runtime;
use nemo::transform::{deploy, DeployOptions};
use nemo::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(artifacts_dir())?;
    let mut rng = Rng::new(4);
    let net = SynthNet::init(&mut rng);
    let dep = deploy(&net.to_pact_graph(8), DeployOptions::default())?;
    let base_args = synthnet_id_args(&dep)?;

    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "max_batch", "clients", "p50 (ms)", "p95 (ms)", "p99 (ms)", "thruput r/s", "mean batch"
    );
    let n_requests = 1024usize;
    for max_batch in [1usize, 4, 16] {
        for clients in [1usize, 8, 32] {
            let model = ModelVariant::load(&rt, "synthnet", "id_fwd_xla", base_args.clone())?;
            let server = Server::start(
                vec![model],
                ServerConfig {
                    max_batch,
                    batch_timeout: Duration::from_micros(300),
                    n_workers: 2,
                },
            );
            let t0 = Instant::now();
            let mut joins = Vec::new();
            for c in 0..clients {
                let h = server.handle();
                let per = n_requests / clients;
                joins.push(std::thread::spawn(move || {
                    let mut data = SynthDigits::new(500 + c as u64);
                    for _ in 0..per {
                        let (x, _) = data.batch(1);
                        let qx = quantize_input(&x, EPS_IN);
                        h.infer("synthnet", qx).expect("infer");
                    }
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
            let wall = t0.elapsed().as_secs_f64();
            let mut m = server.stop();
            println!(
                "{:<10} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>12.0} {:>10.2}",
                max_batch,
                clients,
                m.e2e_latency.percentile(0.50) * 1e3,
                m.e2e_latency.percentile(0.95) * 1e3,
                m.e2e_latency.percentile(0.99) * 1e3,
                m.throughput(wall),
                m.batch_sizes.mean()
            );
        }
    }
    Ok(())
}
