//! Quickstart: the four NEMO representations in ~60 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a small MLP and walks it through the typestate pipeline
//! FullPrecision -> FakeQuantized -> QuantizedDeployable ->
//! IntegerDeployable. Each stage is a distinct *type* — the only methods
//! available are the paper's legal transforms, and every transition
//! consumes the previous stage. The final integer-only network (no
//! floats anywhere on the value path) agrees with the float pipeline.
//! No AOT artifacts required.

use nemo::model::mlp;
use nemo::network::Network;
use nemo::quant::quantize_input;
use nemo::tensor::Tensor;
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let eps_in = 1.0 / 255.0;

    // 1. FullPrecision: an ordinary float network (sec. 1).
    let fp = Network::from_graph(mlp(&mut rng, 64, 48, 10, eps_in))?;
    let x = Tensor::from_vec(
        &[4, 64],
        (0..256).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );
    let fp_out = fp.run(&x);

    // 2. FakeQuantized: PACT clipping bounds from FP calibration (sec. 2).
    let betas = fp.calibrate(&[x.clone()]);
    println!("calibrated PACT betas: {betas:?}");
    let fq = fp.quantize_pact(8, 8, &betas)?;
    let fq_out = fq.run(&x);

    // 3. QuantizedDeployable (harden_weights + bn_quantizer +
    //    set_deployment): still float, every value on its grid.
    let qd = fq.deploy(DeployOptions::default())?;
    let qd_out = qd.run(&x);

    // 4. IntegerDeployable (integerize_pact): quantize the input image
    //    (eps_in = 1/255, sec. 3.7) and run on integer images end to end.
    let id = qd.integerize();
    let qx = quantize_input(&x, eps_in);
    let id_out = id.run(&qx);

    println!("\nlogits for sample 0:");
    println!("  FP : {:?}", &fp_out.data()[..10]);
    println!("  FQ : {:?}", &fq_out.data()[..10]);
    println!("  QD : {:?}", &qd_out.data()[..10]);
    let id_real: Vec<f32> = id_out.data()[..10]
        .iter()
        .map(|q| (*q as f64 * id.eps_out()) as f32)
        .collect();
    println!("  ID : {id_real:?}  (eps_out * integer image)");
    println!("  ID integer image: {:?}", &id_out.data()[..10]);

    assert_eq!(
        fp_out.argmax_rows(),
        id_out.argmax_rows(),
        "integer-only deployment changed the predictions!"
    );
    println!("\nargmax agreement FP == ID on all {} samples ✓", x.shape()[0]);
    println!("max |QD - eps*ID| = {:.2e}", {
        let mut m = 0f64;
        for (a, b) in qd_out.data().iter().zip(id_out.data()) {
            m = m.max((*a as f64 - *b as f64 * id.eps_out()).abs());
        }
        m
    });
    Ok(())
}
