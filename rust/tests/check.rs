//! Static-verifier acceptance suite (`nemo check`, DESIGN.md
//! §Static-verification).
//!
//! Two halves:
//!
//! * **No false alarms, no false safety.** Every randomized
//!   property-test graph that deploys cleanly must produce a zero-error
//!   `CheckReport`, and the intervals the checker derives must contain
//!   the observed runtime values of every node on randomized inputs —
//!   the checker is sound against the actual integer engine, not just
//!   against deploy's own range walk.
//! * **Adversarial artifacts.** Hand-built artifacts with *valid*
//!   checksums but hostile content — out-of-range weights, saturating
//!   or illegal requant parameters, loose precision stamps — decode
//!   fine under the historic contract but must be rejected (or flagged)
//!   by `CheckMode::Strict`, with the specific expected rule id, on
//!   BOTH the JSON and the `.nemob` binary loaders.

use nemo::analysis::{check_graph, rules, CheckMode};
use nemo::engine::IntegerEngine;
use nemo::graph::int::{IntGraph, IntOp};
use nemo::graph::{Graph, Op};
use nemo::io::artifact::{ArtifactError, DeployedArtifact};
use nemo::io::BinLoadMode;
use nemo::network::{Network, StageMeta};
use nemo::quant::bn::BnParams;
use nemo::quant::requant::Requant;
use nemo::quant::{quantize_input, QuantSpec};
use nemo::tensor::{QTensor, Tensor, TensorF};
use nemo::transform::DeployOptions;
use nemo::util::prop::prop_check;
use nemo::util::rng::Rng;

fn rand_w(rng: &mut Rng, shape: &[usize], std: f64) -> TensorF {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal(0.0, std) as f32).collect())
}

fn rand_bn(rng: &mut Rng, c: usize) -> BnParams {
    BnParams {
        gamma: (0..c).map(|_| rng.uniform(0.3, 1.6)).collect(),
        sigma: (0..c).map(|_| rng.uniform(0.3, 1.6)).collect(),
        beta: (0..c).map(|_| rng.normal(0.0, 0.2)).collect(),
        mu: (0..c).map(|_| rng.normal(0.0, 0.2)).collect(),
    }
}

/// Random FullPrecision net (same generator family as tests/plan.rs):
/// conv blocks with optional BN / residual Add / pooling, finished by
/// GlobalAvgPool-or-Flatten + Linear.
fn random_net(rng: &mut Rng) -> (Graph, usize) {
    let mut g = Graph::new(1.0 / 255.0);
    let mut c = rng.int(1, 3) as usize;
    let mut h = 8usize;
    let mut prev = g.push("in", Op::Input { shape: vec![c, h, h] }, &[]);
    let blocks = rng.int(1, 3) as usize;
    for b in 0..blocks {
        let cout = rng.int(2, 6) as usize;
        let k = if rng.int(0, 2) == 0 { 1 } else { 3 };
        let pad = k / 2;
        let stride = if h % 2 == 0 && rng.int(0, 3) == 0 { 2 } else { 1 };
        let std = (0.8 / (c * k * k) as f64).sqrt();
        let w = rand_w(rng, &[cout, c, k, k], std);
        prev = g.push(&format!("c{b}"), Op::Conv2d { w, bias: None, stride, pad }, &[prev]);
        h = (h + 2 * pad - k) / stride + 1;
        c = cout;
        if rng.int(0, 2) == 0 {
            prev = g.push(&format!("bn{b}"), Op::BatchNorm { bn: rand_bn(rng, c) }, &[prev]);
        }
        prev = g.push(&format!("a{b}"), Op::ReLU, &[prev]);
        if rng.int(0, 3) == 0 {
            let std2 = (0.8 / (c * 9) as f64).sqrt();
            let w2 = rand_w(rng, &[c, c, 3, 3], std2);
            let cb = g.push(
                &format!("rc{b}"),
                Op::Conv2d { w: w2, bias: None, stride: 1, pad: 1 },
                &[prev],
            );
            let bb = g.push(&format!("rbn{b}"), Op::BatchNorm { bn: rand_bn(rng, c) }, &[cb]);
            let ab = g.push(&format!("ra{b}"), Op::ReLU, &[bb]);
            let add = g.push(&format!("radd{b}"), Op::Add, &[prev, ab]);
            prev = g.push(&format!("rpa{b}"), Op::ReLU, &[add]);
        }
        if h % 2 == 0 && h > 2 && rng.int(0, 2) == 0 {
            let pool = if rng.int(0, 2) == 0 { Op::MaxPool { k: 2 } } else { Op::AvgPool { k: 2 } };
            prev = g.push(&format!("p{b}"), pool, &[prev]);
            h /= 2;
        }
    }
    let classes = rng.int(2, 6) as usize;
    let (head_in, head) = if rng.int(0, 2) == 0 {
        (c, g.push("gap", Op::GlobalAvgPool, &[prev]))
    } else {
        (c * h * h, g.push("fl", Op::Flatten, &[prev]))
    };
    let wf = rand_w(rng, &[head_in, classes], (1.0 / head_in as f64).sqrt());
    g.push("fc", Op::Linear { w: wf, bias: None }, &[head]);
    let in_c = match &g.nodes[0].op {
        Op::Input { shape } => shape[0],
        _ => unreachable!(),
    };
    (g, in_c)
}

fn rand_input(rng: &mut Rng, b: usize, c: usize) -> TensorF {
    Tensor::from_vec(
        &[b, c, 8, 8],
        (0..b * c * 64).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    )
}

#[test]
fn deployed_nets_check_clean_and_intervals_bound_runtime() {
    prop_check(15, |rng| {
        let (g, in_c) = random_net(rng);
        let b = rng.int(1, 4) as usize;
        let x = rand_input(rng, b, in_c);
        let fp = Network::from_graph(g).map_err(|e| e.to_string())?;
        let betas = fp.calibrate(&[x.clone()]);
        let abits = [1u32, 2, 4, 8][rng.int(0, 4) as usize];
        let wbits = [4u32, 8][rng.int(0, 2) as usize];
        let opts = DeployOptions {
            wbits,
            abits,
            use_thresholds: rng.int(0, 2) == 0,
            ..DeployOptions::default()
        };
        let dep = fp
            .quantize_pact(wbits, abits, &betas)
            .map_err(|e| e.to_string())?
            .deploy(opts)
            .map_err(|e| e.to_string())?
            .integerize()
            .into_deployed();

        // Zero errors on any graph deploy accepted (warnings — loose
        // stamps, missed bit-serial routing — are legitimate findings).
        let report = check_graph(&dep.id);
        if !report.is_sound() {
            return Err(format!(
                "deployed graph flagged unsound:\n{}",
                report.render_human()
            ));
        }
        if report.intervals.len() != dep.id.nodes.len() {
            return Err("one interval per node expected".into());
        }

        // Soundness against the real engine: every value every node
        // produces on this random input must lie inside its interval —
        // no false "safe" verdicts.
        let qx = quantize_input(&x, 1.0 / 255.0);
        let trace = IntegerEngine::new().run_traced(&dep.id, &qx);
        for (id, t) in trace.iter().enumerate() {
            let iv = report.intervals[id];
            for &v in t.data() {
                if !iv.contains(v as i64) {
                    return Err(format!(
                        "node {id} ({}) produced {v} outside derived interval \
                         [{}, {}]",
                        dep.id.nodes[id].name, iv.lo, iv.hi
                    ));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Adversarial artifacts: checksum-valid, decode-valid, statically wrong.
// ---------------------------------------------------------------------

fn tmp_path(tag: &str, ext: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("nemo_check_{tag}_{}.{ext}", std::process::id()))
}

fn u8_spec() -> QuantSpec {
    QuantSpec { eps: 1.0 / 255.0, lo: 0, hi: 255 }
}

/// Wrap a hand-built graph in a full artifact image (the pub-field
/// escape hatch deliberately bypasses deploy — that is the point: these
/// files could come from anywhere).
fn artifact_of(graph: IntGraph) -> DeployedArtifact {
    let n = graph.nodes.len();
    DeployedArtifact {
        graph,
        layers: vec![],
        node_eps: vec![1.0; n],
        worst_case: vec![1],
        meta: StageMeta::default(),
    }
}

/// Save both encodings, assert the decode layer accepts them, and
/// return the Strict-mode rejection rule of each loader.
fn strict_verdicts(art: &DeployedArtifact, tag: &str) -> (Option<&'static str>, Option<&'static str>) {
    let jp = tmp_path(tag, "nemo.json");
    let bp = tmp_path(tag, "nemob");
    art.save(&jp).expect("save json");
    art.save_binary(&bp).expect("save binary");

    // The historic contract still holds: checksum + structural decode
    // pass, so Off-mode loads succeed on both forms.
    DeployedArtifact::load_checked(&jp, CheckMode::Off).expect("json decodes");
    DeployedArtifact::load_binary_checked(&bp, BinLoadMode::Auto, CheckMode::Off)
        .expect("binary decodes");

    let rule_of = |r: Result<DeployedArtifact, ArtifactError>| match r {
        Ok(_) => None,
        Err(ArtifactError::Unsound { rule, .. }) => Some(rule),
        Err(e) => panic!("expected Unsound or success, got {e}"),
    };
    let jr = rule_of(DeployedArtifact::load_checked(&jp, CheckMode::Strict));
    let br = rule_of(
        DeployedArtifact::load_binary_checked(&bp, BinLoadMode::Auto, CheckMode::Strict)
            .map(|(a, _, _)| a),
    );
    let _ = std::fs::remove_file(jp);
    let _ = std::fs::remove_file(bp);
    (jr, br)
}

#[test]
fn out_of_range_weights_are_rejected_as_acc_overflow() {
    // 3x3 conv over a u8 input with 5e6-magnitude i32 weights: fan-in
    // 9 * 5e6 * 255 ~ 1.1e10 >> i32::MAX. Every stamp is "valid" (the
    // accumulator is honestly I32), the checksum is honest — only the
    // interval analysis sees the wrap coming.
    let mut g = IntGraph::default();
    let x = g.push("in", IntOp::Input { shape: vec![1, 4, 4], spec: u8_spec() }, &[]);
    let wq: QTensor = Tensor::from_vec(&[9, 8], vec![5_000_000i32; 72]).into();
    g.push(
        "conv",
        IntOp::ConvInt { wq, bias_q: None, cin: 1, kh: 3, kw: 3, stride: 1, pad: 1 },
        &[x],
    );
    let (jr, br) = strict_verdicts(&artifact_of(g), "hugew");
    assert_eq!(jr, Some(rules::ACC_OVERFLOW));
    assert_eq!(br, Some(rules::ACC_OVERFLOW));
}

#[test]
fn oversized_requant_shift_is_rejected_as_requant_params() {
    // The decode layer accepts any d in 0..=63; the paper's 1/eta bound
    // stops at D_MAX = 40. d = 50 must be a Strict-mode error.
    let mut g = IntGraph::default();
    let x = g.push("in", IntOp::Input { shape: vec![4], spec: u8_spec() }, &[]);
    let wq: QTensor = Tensor::from_vec(&[4, 2], vec![1i32, -1, 2, -2, 1, 1, -1, 2]).into();
    let l = g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[x]);
    g.push(
        "act",
        IntOp::RequantAct { rq: Requant { m: 1 << 45, d: 50, lo: 0, hi: 255 } },
        &[l],
    );
    let (jr, br) = strict_verdicts(&artifact_of(g), "bigd");
    assert_eq!(jr, Some(rules::REQUANT_PARAMS));
    assert_eq!(br, Some(rules::REQUANT_PARAMS));
}

#[test]
fn saturating_wide_requant_is_rejected_as_requant_saturation() {
    // An Add whose branch requant is a pure rescale (full-i32 clip, so
    // clipping is semantically "never happens") but whose pre-clip
    // product reaches 255 * 2^24 ~ 4.3e9: saturation is reachable, the
    // engine would silently clamp-and-wrap.
    let mut g = IntGraph::default();
    let x = g.push("in", IntOp::Input { shape: vec![8], spec: u8_spec() }, &[]);
    g.push(
        "add",
        IntOp::AddRequant {
            rqs: vec![Requant { m: 1 << 24, d: 0, lo: i32::MIN as i64, hi: i32::MAX as i64 }],
        },
        &[x, x],
    );
    let (jr, br) = strict_verdicts(&artifact_of(g), "satrq");
    assert_eq!(jr, Some(rules::REQUANT_SATURATION));
    assert_eq!(br, Some(rules::REQUANT_SATURATION));
}

#[test]
fn loose_precision_stamp_warns_but_still_loads_under_strict() {
    // A requant clipped to [0, 3] (fits U2) stamped I32: the decode
    // re-proof accepts wider-than-natural stamps, so only the checker
    // notices the missed packing. Warning severity — Strict loads it.
    let mut g = IntGraph::default();
    let x = g.push("in", IntOp::Input { shape: vec![4], spec: u8_spec() }, &[]);
    let wq: QTensor = Tensor::from_vec(&[4, 2], vec![1i32, -1, 1, -1, 2, -2, 2, -2]).into();
    let l = g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[x]);
    let act = g.push(
        "act",
        IntOp::RequantAct { rq: Requant { m: 1, d: 8, lo: 0, hi: 3 } },
        &[l],
    );
    g.stamp_precision(act, nemo::quant::Precision::I32);
    let art = artifact_of(g);
    let (jr, br) = strict_verdicts(&art, "loose");
    assert_eq!(jr, None, "warnings must not fail Strict");
    assert_eq!(br, None);
    let report = check_graph(&art.graph);
    let f = report
        .findings
        .iter()
        .find(|f| f.rule == rules::PRECISION_LOOSE)
        .expect("loose stamp flagged");
    assert_eq!(f.node, Some(act));
}

#[test]
fn loose_stamp_also_costs_the_bitserial_route() {
    // Same loose-stamp graph extended by a second GEMM with few-bit
    // weights: the interval [0, 3] would qualify it for the bit-serial
    // path, but the U8 stamp keeps it on the MAC kernels — the checker
    // connects the two with a bitserial-missed warning.
    let mut g = IntGraph::default();
    let x = g.push("in", IntOp::Input { shape: vec![4], spec: u8_spec() }, &[]);
    let wq: QTensor = Tensor::from_vec(&[4, 4], vec![1i32; 16]).into();
    let l = g.push("fc1", IntOp::LinearInt { wq, bias_q: None }, &[x]);
    let act = g.push(
        "act",
        IntOp::RequantAct { rq: Requant { m: 1, d: 9, lo: 0, hi: 3 } },
        &[l],
    );
    g.stamp_precision(act, nemo::quant::Precision::U8);
    let wq2: QTensor = Tensor::from_vec(&[4, 2], vec![1i32, -1, 1, -1, 1, 1, -1, -1]).into();
    let out = g.push("fc2", IntOp::LinearInt { wq: wq2, bias_q: None }, &[act]);
    g.output = out;
    let report = check_graph(&g);
    assert!(report.is_sound(), "{}", report.render_human());
    let f = report
        .findings
        .iter()
        .find(|f| f.rule == rules::BITSERIAL_MISSED)
        .expect("missed bit-serial routing flagged");
    assert_eq!(f.node, Some(out));
}

#[test]
fn check_json_schema_is_stable_on_a_real_artifact() {
    // The CI round-trip job greps these exact fields out of
    // `nemo check --json`; pin them here too so the schema cannot
    // drift silently.
    let mut rng = Rng::new(42);
    let (g, in_c) = random_net(&mut rng);
    let x = rand_input(&mut rng, 2, in_c);
    let fp = Network::from_graph(g).unwrap();
    let betas = fp.calibrate(&[x.clone()]);
    let dep = fp
        .quantize_pact(8, 8, &betas)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize()
        .into_deployed();
    let text = check_graph(&dep.id).to_json("m.nemo.json");
    let v = nemo::util::json::parse(&text).unwrap();
    assert_eq!(v.get("format").unwrap().as_str().unwrap(), "nemo-check-report");
    assert_eq!(v.get("version").unwrap().as_i64().unwrap(), 1);
    assert_eq!(v.get("errors").unwrap().as_i64().unwrap(), 0);
    assert_eq!(v.get("source").unwrap().as_str().unwrap(), "m.nemo.json");
    let rule_ids: Vec<&str> = v
        .get("rules")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.get("id").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(rule_ids, rules::ALL);
}
