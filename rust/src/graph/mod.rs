//! DNN graph IR (S2 in DESIGN.md).
//!
//! Two graph flavours carry the four representations:
//!
//! * [`Graph`] (float ops) — FullPrecision, FakeQuantized and
//!   QuantizedDeployable. The representation is encoded by *which* ops
//!   appear: `BatchNorm`+`ReLU` (FP), `PactAct` + hardened weights (FQ),
//!   `QuantBn`+`PactAct` (QD).
//! * [`IntGraph`](crate::transform::IntGraph) (integer ops) —
//!   IntegerDeployable; built by the transform pipeline. Every integer
//!   node additionally carries a stamped storage
//!   [`Precision`](crate::quant::Precision) (u8/i8/i32) derived from its
//!   provable value range; [`shape::infer_precision`] validates the
//!   stamps and the plan compiler dispatches packed kernels on them
//!   (DESIGN.md §Precision propagation).
//!
//! The paper's layer rule (sec. 1: a layer is a linear sequence ending at
//! the first Activation; branches may only start at Activation outputs)
//! is enforced by [`Graph::validate`].

use crate::quant::bn::BnParams;
use crate::quant::QuantSpec;
use crate::tensor::TensorF;

pub type NodeId = usize;

/// Float-domain operator (FP / FQ / QD representations).
#[derive(Clone, Debug)]
pub enum Op {
    /// Network input, NCHW shape (without batch) or [features].
    Input { shape: Vec<usize> },
    /// Convolution, weights OIHW. Bias is per-output-channel.
    Conv2d {
        w: TensorF,
        bias: Option<Vec<f64>>,
        stride: usize,
        pad: usize,
    },
    /// Fully-connected, weights [in, out].
    Linear { w: TensorF, bias: Option<Vec<f64>> },
    /// Batch normalization (inference form, sec. 1.2).
    BatchNorm { bn: BnParams },
    /// Quantized BN for the QD representation: phi*kappa_hat + lambda_hat
    /// where both parameters are on their quantized grids (sec. 3.4).
    QuantBn { kappa_hat: Vec<f64>, lambda_hat: Vec<f64> },
    /// Plain ReLU (FP).
    ReLU,
    /// PACT quantization/activation (FQ and QD; Eq. 10):
    /// y = eps_y * clip(floor(t/eps_y), 0, (2^bits)-1), eps_y = beta/(2^bits-1).
    PactAct { beta: f64, bits: u32 },
    MaxPool { k: usize },
    AvgPool { k: usize },
    GlobalAvgPool,
    Flatten,
    /// Element-wise addition of all inputs (sec. 3.5).
    Add,
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Input { .. } => "Input",
            Op::Conv2d { .. } => "Conv2d",
            Op::Linear { .. } => "Linear",
            Op::BatchNorm { .. } => "BatchNorm",
            Op::QuantBn { .. } => "QuantBn",
            Op::ReLU => "ReLU",
            Op::PactAct { .. } => "PactAct",
            Op::MaxPool { .. } => "MaxPool",
            Op::AvgPool { .. } => "AvgPool",
            Op::GlobalAvgPool => "GlobalAvgPool",
            Op::Flatten => "Flatten",
            Op::Add => "Add",
        }
    }

    /// Linear class per sec. 1 (Linear operators).
    pub fn is_linear(&self) -> bool {
        matches!(self, Op::Conv2d { .. } | Op::Linear { .. })
    }

    /// Activation class per sec. 1.
    pub fn is_activation(&self) -> bool {
        matches!(self, Op::ReLU | Op::PactAct { .. })
    }
}

#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    /// Optional label for diagnostics / transform bookkeeping.
    pub name: String,
}

#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Node whose output is the network output.
    pub output: NodeId,
    /// Quantum of the network input (sec. 3.7); informs set_deployment.
    pub eps_in: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum GraphError {
    #[error("graph has a cycle or forward reference at node {0}")]
    NotTopological(NodeId),
    #[error("node {0} ({1}) has {2} inputs, expected {3}")]
    Arity(NodeId, &'static str, usize, usize),
    #[error("layer rule violated: branch from non-activation node {0} ({1}) (sec. 1)")]
    BranchRule(NodeId, &'static str),
    #[error("graph has no Input node")]
    NoInput,
}

impl Graph {
    pub fn new(eps_in: f64) -> Self {
        Graph { nodes: Vec::new(), output: 0, eps_in }
    }

    /// Append a node; returns its id. Inputs must already exist
    /// (construction is therefore always topological).
    pub fn push(&mut self, name: &str, op: Op, inputs: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "forward reference {i} >= {id}");
        }
        self.nodes.push(Node { id, op, inputs: inputs.to_vec(), name: name.to_string() });
        self.output = id;
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Number of consumers of each node.
    pub fn fanout(&self) -> Vec<usize> {
        let mut f = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                f[i] += 1;
            }
        }
        f
    }

    /// Validate topology, arities, and the paper's layer/branch rule.
    pub fn validate(&self) -> Result<(), GraphError> {
        if !self.nodes.iter().any(|n| matches!(n.op, Op::Input { .. })) {
            return Err(GraphError::NoInput);
        }
        for n in &self.nodes {
            for &i in &n.inputs {
                if i >= n.id {
                    return Err(GraphError::NotTopological(n.id));
                }
            }
            match n.op {
                // Add is variadic with a lower bound of two inputs.
                Op::Add => {
                    if n.inputs.len() < 2 {
                        return Err(GraphError::Arity(n.id, n.op.name(), n.inputs.len(), 2));
                    }
                }
                Op::Input { .. } => {
                    if !n.inputs.is_empty() {
                        return Err(GraphError::Arity(n.id, n.op.name(), n.inputs.len(), 0));
                    }
                }
                _ => {
                    if n.inputs.len() != 1 {
                        return Err(GraphError::Arity(n.id, n.op.name(), n.inputs.len(), 1));
                    }
                }
            }
        }
        // Branch rule (sec. 1): any node with fanout > 1 must be an
        // Activation (or the Input itself).
        let fanout = self.fanout();
        for n in &self.nodes {
            if fanout[n.id] > 1
                && !n.op.is_activation()
                && !matches!(n.op, Op::Input { .. })
            {
                return Err(GraphError::BranchRule(n.id, n.op.name()));
            }
        }
        Ok(())
    }

    /// Extract the paper's layers: maximal linear chains each ending at
    /// the first Activation (sec. 1). Returns slices of node ids.
    pub fn layers(&self) -> Vec<Vec<NodeId>> {
        let mut layers = Vec::new();
        let mut current: Vec<NodeId> = Vec::new();
        for n in &self.nodes {
            if matches!(n.op, Op::Input { .. }) {
                continue;
            }
            current.push(n.id);
            if n.op.is_activation() {
                layers.push(std::mem::take(&mut current));
            }
        }
        if !current.is_empty() {
            layers.push(current);
        }
        layers
    }

    /// Ids of all activation nodes in order (calibration points).
    pub fn activations(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.op.is_activation())
            .map(|n| n.id)
            .collect()
    }

    /// Input quantization spec implied by eps_in (8-bit camera-style
    /// input: eps = 1/255 -> [0, 255]).
    pub fn input_spec(&self) -> QuantSpec {
        let hi = (1.0 / self.eps_in).round() as i64;
        QuantSpec { eps: self.eps_in, lo: 0, hi }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_graph() -> Graph {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 4, 4] }, &[]);
        let w = Tensor::zeros(&[2, 1, 3, 3]);
        let c = g.push(
            "conv",
            Op::Conv2d { w, bias: None, stride: 1, pad: 1 },
            &[x],
        );
        let b = g.push("bn", Op::BatchNorm { bn: BnParams::identity(2) }, &[c]);
        g.push("act", Op::ReLU, &[b]);
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny_graph();
        assert!(g.validate().is_ok());
        assert_eq!(g.layers().len(), 1);
        assert_eq!(g.layers()[0].len(), 3);
    }

    #[test]
    fn branch_from_activation_is_legal() {
        let mut g = tiny_graph();
        let act = g.output;
        let w = Tensor::zeros(&[2, 2, 3, 3]);
        let c1 = g.push("c1", Op::Conv2d { w: w.clone(), bias: None, stride: 1, pad: 1 }, &[act]);
        let r1 = g.push("r1", Op::ReLU, &[c1]);
        g.push("add", Op::Add, &[act, r1]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn branch_from_linear_is_rejected() {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 4, 4] }, &[]);
        let w = Tensor::zeros(&[2, 1, 3, 3]);
        let c = g.push("conv", Op::Conv2d { w, bias: None, stride: 1, pad: 1 }, &[x]);
        let r1 = g.push("r1", Op::ReLU, &[c]);
        let r2 = g.push("r2", Op::ReLU, &[c]); // second consumer of conv
        g.push("add", Op::Add, &[r1, r2]);
        assert!(matches!(g.validate(), Err(GraphError::BranchRule(_, _))));
    }

    #[test]
    fn add_arity_enforced() {
        let mut g = tiny_graph();
        let act = g.output;
        g.push("add", Op::Add, &[act]);
        assert!(matches!(g.validate(), Err(GraphError::Arity(_, _, 1, 2))));
    }
}

pub mod grad;
pub mod int;
pub mod shape;
