//! Cross-language golden validation: the Rust deployment pipeline must
//! reproduce the Python reference (python/compile/deploy.py) bit-exactly
//! on integer outputs, and the three execution paths —
//! IntegerEngine (Rust), PJRT id_fwd artifact (Pallas kernels), Python
//! golden — must agree exactly (experiment E9's exactness half).
//!
//! Requires `make artifacts`. Tests skip (with a note) if absent.

use nemo::engine::{FloatEngine, IntegerEngine};
use nemo::io::{artifacts_dir, Goldens};
use nemo::model::artifact_args::synthnet_id_args;
use nemo::model::synthnet::SynthNet;
use nemo::quant::bn::{BnParams, BnQuant, Thresholds};
use nemo::quant::requant::{choose_d, multiplier};
#[cfg(feature = "pjrt")]
use nemo::runtime::Runtime;
#[cfg(feature = "pjrt")]
use nemo::tensor::Tensor;
use nemo::tensor::TensorF;
use nemo::transform::DeployOptions;

fn goldens() -> Option<Goldens> {
    let dir = artifacts_dir();
    if !dir.join("goldens.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Goldens::load(dir).unwrap())
}

fn net_from_goldens(g: &Goldens) -> SynthNet {
    let p = |name: &str| g.tensor_f32(&["model_case", "params", name]).unwrap();
    let v64 = |name: &str| -> Vec<f64> {
        g.walk(&["model_case", "params", name])
            .unwrap()
            .as_f64_tensor()
            .unwrap()
            .0
    };
    let s64 = |name: &str| -> Vec<f64> {
        g.walk(&["model_case", "bn_state", name])
            .unwrap()
            .as_f64_tensor()
            .unwrap()
            .0
    };
    let mut net = SynthNet {
        convs: vec![
            (p("conv1.w"), v64("conv1.bn_gamma"), v64("conv1.bn_beta")),
            (p("conv2.w"), v64("conv2.bn_gamma"), v64("conv2.bn_beta")),
            (p("conv3.w"), v64("conv3.bn_gamma"), v64("conv3.bn_beta")),
        ],
        bn_state: vec![
            (s64("conv1.bn_mu"), s64("conv1.bn_var")),
            (s64("conv2.bn_mu"), s64("conv2.bn_var")),
            (s64("conv3.bn_mu"), s64("conv3.bn_var")),
        ],
        fc_w: p("fc.w"),
        fc_b: v64("fc.b"),
        act_betas: vec![],
    };
    let (betas, _) = g
        .walk(&["model_case", "act_betas"])
        .unwrap()
        .as_f64_tensor()
        .unwrap();
    net.act_betas = betas;
    net
}

fn deployed_from_goldens(g: &Goldens) -> nemo::transform::Deployed {
    let net = net_from_goldens(g);
    net.to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize()
        .into_deployed()
}

#[test]
fn requant_params_match_python() {
    let Some(g) = goldens() else { return };
    let cases = g.walk(&["requant_param_cases"]).unwrap().as_arr().unwrap();
    assert!(cases.len() >= 32);
    for c in cases {
        let eps_a = c.get("eps_a").unwrap().as_f64().unwrap();
        let eps_b = c.get("eps_b").unwrap().as_f64().unwrap();
        let factor = c.get("factor").unwrap().as_i64().unwrap() as u32;
        let d = choose_d(eps_a, eps_b, factor)
            .expect("golden requant cases never saturate");
        let m = multiplier(eps_a, eps_b, d);
        assert_eq!(d as i64, c.get("d").unwrap().as_i64().unwrap(), "d mismatch");
        assert_eq!(m, c.get("m").unwrap().as_i64().unwrap(), "m mismatch");
    }
}

#[test]
fn bn_quantization_matches_python() {
    let Some(g) = goldens() else { return };
    let case = g.walk(&["bn_quant_case"]).unwrap();
    let bn = BnParams {
        gamma: case.get("gamma").unwrap().as_f64_tensor().unwrap().0,
        sigma: case.get("sigma").unwrap().as_f64_tensor().unwrap().0,
        beta: case.get("beta").unwrap().as_f64_tensor().unwrap().0,
        mu: case.get("mu").unwrap().as_f64_tensor().unwrap().0,
    };
    let eps_phi = case.get("eps_phi").unwrap().as_f64().unwrap();
    let bq = BnQuant::derive(&bn, eps_phi, 8);
    let want_k: Vec<i64> = case
        .get("kappa_q").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_i64().unwrap()).collect();
    let want_l: Vec<i64> = case
        .get("lambda_q").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_i64().unwrap()).collect();
    assert_eq!(bq.kappa_q.iter().map(|v| *v as i64).collect::<Vec<_>>(), want_k);
    assert_eq!(bq.lambda_q.iter().map(|v| *v as i64).collect::<Vec<_>>(), want_l);
    assert_eq!(
        bq.eps_kappa.to_bits(),
        case.get("eps_kappa").unwrap().as_f64().unwrap().to_bits(),
        "eps_kappa must match to the last bit"
    );
}

#[test]
fn thresholds_match_python() {
    let Some(g) = goldens() else { return };
    let case = g.walk(&["thresholds_case"]).unwrap();
    let bn = BnParams {
        gamma: case.get("gamma").unwrap().as_f64_tensor().unwrap().0,
        sigma: case.get("sigma").unwrap().as_f64_tensor().unwrap().0,
        beta: case.get("beta").unwrap().as_f64_tensor().unwrap().0,
        mu: case.get("mu").unwrap().as_f64_tensor().unwrap().0,
    };
    let eps_phi = case.get("eps_phi").unwrap().as_f64().unwrap();
    let eps_y = case.get("eps_y").unwrap().as_f64().unwrap();
    let n = case.get("n_levels").unwrap().as_i64().unwrap();
    // python bn_thresholds emits TH_1..TH_{n-1} (range(1, n_levels))
    let th = Thresholds::derive(&bn, eps_phi, eps_y, n - 1);
    let (want, shape) = case.get("thresholds").unwrap().as_f64_tensor().unwrap();
    assert_eq!(shape[0], th.th.len());
    for (c, row) in th.th.iter().enumerate() {
        for (i, v) in row.iter().enumerate() {
            assert_eq!(*v as f64, want[c * shape[1] + i], "TH[{c}][{i}]");
        }
    }
}

#[test]
fn fold_bn_matches_python() {
    let Some(g) = goldens() else { return };
    let case = g.walk(&["fold_bn_case"]).unwrap();
    let bn = BnParams {
        gamma: case.get("gamma").unwrap().as_f64_tensor().unwrap().0,
        sigma: case.get("sigma").unwrap().as_f64_tensor().unwrap().0,
        beta: case.get("beta").unwrap().as_f64_tensor().unwrap().0,
        mu: case.get("mu").unwrap().as_f64_tensor().unwrap().0,
    };
    let (w, wshape) = case.get("w").unwrap().as_f64_tensor().unwrap();
    let (kappa, lambda) = bn.fold();
    let (want_w, _) = case.get("w_folded").unwrap().as_f64_tensor().unwrap();
    let (want_b, _) = case.get("b_folded").unwrap().as_f64_tensor().unwrap();
    let per: usize = wshape[1..].iter().product();
    for oc in 0..wshape[0] {
        for k in 0..per {
            let got = kappa[oc] * w[oc * per + k];
            assert!((got - want_w[oc * per + k]).abs() < 1e-15);
        }
        assert!((lambda[oc] - want_b[oc]).abs() < 1e-15);
    }
}

#[test]
fn deployment_params_match_python_exactly() {
    // The full-pipeline contract: identical integer deployment parameters
    // from identical float weights.
    let Some(g) = goldens() else { return };
    let dep = deployed_from_goldens(&g);
    let args = synthnet_id_args(&dep).unwrap();
    let names = [
        "conv1.wq", "conv1.kappa_q", "conv1.lambda_q", "conv1.m", "conv1.d",
        "conv1.act_hi", "conv2.wq", "conv2.kappa_q", "conv2.lambda_q",
        "conv2.m", "conv2.d", "conv2.act_hi", "conv3.wq", "conv3.kappa_q",
        "conv3.lambda_q", "conv3.m", "conv3.d", "conv3.act_hi", "fc.wq",
        "fc.bq",
    ];
    assert_eq!(args.len(), names.len());
    for (arg, name) in args.iter().zip(names) {
        let want = g.tensor_i32(&["model_case", "id_args", name]).unwrap();
        let got = arg.as_i32().unwrap();
        assert_eq!(
            got.data(),
            want.data(),
            "integer deployment param '{name}' diverges from python"
        );
    }
    let want_eps = g.f64(&["model_case", "eps_out"]).unwrap();
    assert_eq!(dep.eps_out.to_bits(), want_eps.to_bits(), "eps_out");
}

#[test]
fn integer_engine_matches_python_golden() {
    let Some(g) = goldens() else { return };
    let dep = deployed_from_goldens(&g);
    let qx = g.tensor_i32(&["model_case", "qx"]).unwrap();
    let want = g.tensor_i32(&["model_case", "id_qlogits"]).unwrap();
    let got = IntegerEngine::new().run(&dep.id, &qx);
    assert_eq!(got.data(), want.data(), "integer logits must be bit-exact");
}

#[test]
fn float_engine_matches_python_fp() {
    let Some(g) = goldens() else { return };
    let net = net_from_goldens(&g);
    let x = g.tensor_f32(&["model_case", "x"]).unwrap();
    let want = g.tensor_f32(&["model_case", "fp_logits"]).unwrap();
    let got = FloatEngine::new().run(&net.to_fp_graph(), &x);
    assert!(
        got.allclose(&want, 1e-3, 1e-3),
        "FP logits diverge: max diff {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn qd_engine_matches_python_qd() {
    let Some(g) = goldens() else { return };
    let dep = deployed_from_goldens(&g);
    let qx = g.tensor_i32(&["model_case", "qx"]).unwrap();
    let x_grid: TensorF = qx.map(|q| q as f32 / 255.0);
    let want = g.tensor_f32(&["model_case", "qd_logits"]).unwrap();
    let got = FloatEngine::new().run(&dep.qd, &x_grid);
    assert!(
        got.allclose(&want, 2e-3, 2e-3),
        "QD logits diverge: max diff {}",
        got.max_abs_diff(&want)
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_id_artifact_matches_integer_engine_bit_exactly() {
    // E9: the Pallas-kernel HLO graph (via PJRT) and the Rust integer
    // engine are the same function — bit-exact integer outputs.
    let Some(g) = goldens() else { return };
    let dir = artifacts_dir();
    let rt = Runtime::new(&dir).unwrap();
    let dep = deployed_from_goldens(&g);
    let qx = g.tensor_i32(&["model_case", "qx"]).unwrap();

    let exe = rt.load("synthnet_id_fwd_b2").unwrap();
    let mut args = synthnet_id_args(&dep).unwrap();
    args.push(qx.clone().into());
    let outs = exe.run(&args).unwrap();
    let pjrt_out = outs[0].as_i32().unwrap();

    let engine_out = IntegerEngine::new().run(&dep.id, &qx);
    assert_eq!(pjrt_out.data(), engine_out.data(), "PJRT vs engine");

    let want = g.tensor_i32(&["model_case", "id_qlogits"]).unwrap();
    assert_eq!(pjrt_out.data(), want.data(), "PJRT vs python golden");
}

#[cfg(feature = "pjrt")]
#[test]
fn kernel_goldens_roundtrip_through_pjrt() {
    let Some(g) = goldens() else { return };
    let rt = Runtime::new(artifacts_dir()).unwrap();

    // requant kernel over golden case (padded to the artifact's 64k shape)
    let q = g.tensor_i32(&["requant_case", "q"]).unwrap();
    let want = g.tensor_i32(&["requant_case", "out"]).unwrap();
    let exe = rt.load("kernel_requant_64k").unwrap();
    let mut data = q.data().to_vec();
    data.resize(65536, 0);
    let args = vec![
        Tensor::from_vec(&[65536], data).into(),
        Tensor::scalar(29i32).into(),
        Tensor::scalar(21i32).into(),
        Tensor::scalar(0i32).into(),
        Tensor::scalar(255i32).into(),
    ];
    let outs = exe.run(&args).unwrap();
    let got = outs[0].as_i32().unwrap();
    assert_eq!(&got.data()[..q.len()], want.data());
}
